"""Training-substrate tests: optimizers, checkpointing (atomic/async/
reshard), failure recovery, straggler detection, gradient compression."""
import os
import subprocess
import sys
import tempfile
import threading
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ck
from repro.train import compression as comp
from repro.train import optimizer as opt_mod
from repro.train.fault_tolerance import Heartbeat, HeartbeatMonitor, StragglerDetector, run_with_recovery
from repro.train.loop import TrainConfig, fit, make_train_step

KEY = jax.random.PRNGKey(0)
REPO = Path(__file__).resolve().parent.parent


def _toy_problem():
    W = jax.random.normal(KEY, (8, 8))

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"] + params["b"]
        l = jnp.mean((pred - batch["y"]) ** 2)
        return l, {"mse": l}

    def data_iter(start):
        i = start
        while True:
            k = jax.random.fold_in(KEY, i)
            x = jax.random.normal(k, (32, 8))
            yield {"x": x, "y": x @ W}
            i += 1

    params = {"w": jnp.zeros((8, 8)), "b": jnp.zeros((8,))}
    return params, loss_fn, data_iter


class TestOptimizers:
    @pytest.mark.parametrize("make", [
        lambda: opt_mod.adamw(lr=3e-2, weight_decay=0.0),
        lambda: opt_mod.adafactor(lr=3e-2),
        lambda: opt_mod.sgd(lr=0.3, momentum=0.9),
    ], ids=["adamw", "adafactor", "sgd"])
    def test_converges_on_quadratic(self, make):
        params, loss_fn, data_iter = _toy_problem()
        opt = make()
        step = make_train_step(loss_fn, opt)
        state = opt.init(params)
        it = data_iter(0)
        first = None
        for _ in range(80):
            params, state, m = step(params, state, next(it))
            first = first if first is not None else float(m["loss"])
        assert float(m["loss"]) < 0.2 * first

    def test_bf16_params_master_fp32(self):
        params = {"w": jnp.zeros((16, 16), jnp.bfloat16)}
        opt = opt_mod.adamw(lr=1e-2, weight_decay=0.0)
        state = opt.init(params)
        g = {"w": jnp.full((16, 16), 1e-3, jnp.bfloat16)}
        p1, state = opt.update(g, state, params)
        assert p1["w"].dtype == jnp.bfloat16
        assert state["master"]["w"].dtype == jnp.float32
        # tiny updates accumulate in the master even below bf16 resolution
        for _ in range(5):
            p1, state = opt.update(g, state, p1)
        assert float(jnp.abs(state["master"]["w"]).max()) > 0

    def test_adafactor_state_is_factored(self):
        params = {"w": jnp.zeros((64, 32)), "b": jnp.zeros((32,))}
        opt = opt_mod.adafactor()
        st = opt.init(params)
        assert st["v"]["w"]["vr"].shape == (64,)
        assert st["v"]["w"]["vc"].shape == (32,)
        assert st["v"]["b"]["v"].shape == (32,)
        # factored state is ~(n+m)/(n·m) of Adam's
        adam_bytes = 2 * 64 * 32
        fac_bytes = 64 + 32
        assert fac_bytes < 0.1 * adam_bytes


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(12.0).reshape(3, 4), "n": {"b": jnp.ones((5,), jnp.int32)}}
        ck.save(tmp_path, 7, tree)
        got, step = ck.restore(tmp_path, tree)
        assert step == 7
        np.testing.assert_array_equal(got["a"], tree["a"])
        np.testing.assert_array_equal(got["n"]["b"], tree["n"]["b"])

    def test_latest_pointer_and_fallback(self, tmp_path):
        tree = {"a": jnp.zeros((2,))}
        ck.save(tmp_path, 1, tree)
        ck.save(tmp_path, 5, tree)
        assert ck.latest_step(tmp_path) == 5
        (tmp_path / "LATEST").unlink()  # simulate crash before pointer write
        assert ck.latest_step(tmp_path) == 5

    def test_interrupted_save_never_corrupts(self, tmp_path):
        tree = {"a": jnp.ones((4,))}
        ck.save(tmp_path, 1, tree)
        # a stale tmp dir from a crashed save must be ignored
        (tmp_path / "ckpt_2.tmp.dead").mkdir()
        assert ck.latest_step(tmp_path) == 1
        got, step = ck.restore(tmp_path, tree)
        assert step == 1

    def test_async_checkpointer(self, tmp_path):
        acp = ck.AsyncCheckpointer(tmp_path)
        tree = {"a": jnp.arange(1000.0)}
        acp.save(3, tree)
        acp.wait()
        got, step = ck.restore(tmp_path, tree)
        assert step == 3
        np.testing.assert_array_equal(got["a"], tree["a"])

    def test_stale_latest_pointer_falls_back(self, tmp_path):
        # crash AFTER a rename of step 3's dir but with LATEST still naming
        # a step that never completed: the pointer is a hint, not truth
        tree = {"a": jnp.zeros((2,))}
        ck.save(tmp_path, 3, tree)
        (tmp_path / "LATEST").write_text("9")
        assert ck.latest_step(tmp_path) == 3
        _, step = ck.restore(tmp_path, tree)
        assert step == 3

    def test_crash_between_write_and_rename_keeps_previous(self, tmp_path):
        # an exception inside the atomic window must delete the tmp dir and
        # leave the previous snapshot byte-for-byte untouched
        tree = {"a": jnp.arange(4.0)}
        ck.save(tmp_path, 1, tree)

        class Boom(Exception):
            pass

        with pytest.raises(Boom):
            with ck.atomic_snapshot_dir(tmp_path, "ckpt_2") as tmp:
                (tmp / "manifest.json").write_text("{}")
                raise Boom()
        assert not list(tmp_path.glob("*.tmp.*"))   # no half-written debris
        assert not (tmp_path / "ckpt_2").exists()   # nothing partial renamed
        got, step = ck.restore(tmp_path, tree)
        assert step == 1
        np.testing.assert_array_equal(got["a"], tree["a"])

    def test_async_checkpointer_surfaces_error_on_wait(self, tmp_path, monkeypatch):
        acp = ck.AsyncCheckpointer(tmp_path)

        def bad_save(*a, **k):
            raise OSError("disk full")

        monkeypatch.setattr(ck, "save", bad_save)
        acp.save(1, {"a": jnp.zeros((2,))})
        with pytest.raises(OSError, match="disk full"):
            acp.wait()
        acp.wait()  # the error is surfaced ONCE, then cleared
        monkeypatch.undo()
        acp.save(2, {"a": jnp.zeros((2,))})  # checkpointer still usable
        acp.wait()
        assert ck.latest_step(tmp_path) == 2


class TestRecovery:
    def test_fit_recovers_from_injected_failure(self, tmp_path):
        params, loss_fn, data_iter = _toy_problem()
        cfg = TrainConfig(steps=60, ckpt_every=20, ckpt_dir=str(tmp_path), log_every=20)
        p, o, logs = fit(params=params, optimizer=opt_mod.adamw(lr=3e-2, weight_decay=0.0),
                         loss_fn=loss_fn, data_iter_fn=data_iter, cfg=cfg, _fail_at=45)
        assert logs[-1]["mse"] < 1.0
        assert ck.latest_step(tmp_path) == 59

    def test_run_with_recovery_gives_up_after_max(self):
        calls = {"n": 0}

        def run(start):
            calls["n"] += 1
            raise RuntimeError("boom")

        with pytest.raises(RuntimeError):
            run_with_recovery(run, lambda: 0, max_failures=2)
        assert calls["n"] == 3  # initial + 2 retries

    def test_heartbeat_monitor_detects_hang(self):
        hb = Heartbeat()
        hung = threading.Event()
        mon = HeartbeatMonitor(hb, timeout=0.2, on_hang=hung.set).start()
        try:
            assert hung.wait(timeout=3.0)
        finally:
            mon.stop()

    def test_straggler_detector(self):
        det = StragglerDetector(window=32, threshold=3.0, warmup=8)
        flagged = [det.observe(0.1 + 0.001 * (i % 3)) for i in range(20)]
        assert not any(flagged)
        assert det.observe(1.5)  # 15x slower step
        assert len(det.events) == 1


class TestCompression:
    def test_int8_error_feedback_unbiased(self):
        g = {"w": jax.random.normal(KEY, (64, 32))}
        err = comp.init_error_tree(g)
        acc_raw = jnp.zeros((64, 32))
        acc_cmp = jnp.zeros((64, 32))
        for i in range(50):
            gi = {"w": jax.random.normal(jax.random.fold_in(KEY, i), (64, 32))}
            dq, err = comp.int8_compress_tree(gi, err)
            acc_raw += gi["w"]
            acc_cmp += dq["w"]
        rel = float(jnp.linalg.norm(acc_raw - acc_cmp) / jnp.linalg.norm(acc_raw))
        assert rel < 0.01

    def test_powersgd_low_rank_quality(self):
        # a genuinely low-rank gradient should be captured almost exactly
        u = jax.random.normal(KEY, (64, 3))
        v = jax.random.normal(jax.random.fold_in(KEY, 1), (3, 48))
        g = {"w": u @ v}
        st = comp.init_powersgd(g, rank=4, key=KEY)
        for _ in range(3):  # a few power iterations via warm-started Q
            approx, st = comp.powersgd_round(g, st, None)
        rel = float(jnp.linalg.norm(approx["w"] - g["w"]) / jnp.linalg.norm(g["w"]))
        assert rel < 1e-2

    def test_compression_ratio(self):
        params = {"w": jnp.zeros((1024, 1024))}
        assert comp.compression_ratio(params, 4) < 0.01


DP_CHECK = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.train import optimizer as opt_mod, compression as comp
from repro.train.loop import make_explicit_dp_step, make_train_step
assert jax.device_count() == 8
mesh = jax.make_mesh((8,), ("data",))
KEY = jax.random.PRNGKey(0)
W = jax.random.normal(KEY, (8, 8))
def loss_fn(params, batch):
    pred = batch["x"] @ params["w"]
    l = jnp.mean((pred - batch["y"]) ** 2)
    return l, {"mse": l}
params = {"w": jnp.zeros((8, 8))}
opt = opt_mod.sgd(lr=0.2)
for compression in (None, "int8", "powersgd"):
    step, init_comp = make_explicit_dp_step(loss_fn, opt, mesh, batch_axes=("data",),
                                            compression=compression, powersgd_rank=4)
    p = {"w": jnp.zeros((8, 8))}
    st = opt.init(p)
    cs = init_comp(p, KEY)
    for i in range(60):
        k = jax.random.fold_in(KEY, i)
        x = jax.random.normal(k, (64, 8))
        batch = {"x": x, "y": x @ W}
        p, st, cs, m = step(p, st, cs, batch)
    final = float(m["loss"])
    print(compression, final)
    assert final < 0.05, (compression, final)
print("DP-COMPRESSION-OK")
"""


@pytest.mark.slow
def test_explicit_dp_compressed_allreduce_8dev():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run([sys.executable, "-c", DP_CHECK], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "DP-COMPRESSION-OK" in out.stdout


ELASTIC_CHECK = r"""
import jax, jax.numpy as jnp, numpy as np, tempfile
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.train import checkpoint as ck
assert jax.device_count() == 8
tree = {"w": jnp.arange(64.0).reshape(8, 8), "b": jnp.ones((8,))}
with tempfile.TemporaryDirectory() as d:
    # save from a 4-device data mesh
    mesh4 = jax.make_mesh((4,), ("data",), devices=jax.devices()[:4])
    sharded = {
        "w": jax.device_put(tree["w"], NamedSharding(mesh4, P("data", None))),
        "b": jax.device_put(tree["b"], NamedSharding(mesh4, P())),
    }
    ck.save(d, 11, sharded)
    # restore onto a DIFFERENT (2x4) mesh with different specs — elastic reshard
    mesh8 = jax.make_mesh((2, 4), ("data", "model"))
    specs = {"w": P("model", "data"), "b": P("data")}
    got, step = ck.restore(d, tree, mesh=mesh8, specs=specs)
    assert step == 11
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(tree["w"]))
    assert got["w"].sharding.spec == specs["w"]
print("ELASTIC-OK")
"""


@pytest.mark.slow
def test_elastic_checkpoint_reshard_8dev():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run([sys.executable, "-c", ELASTIC_CHECK], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "ELASTIC-OK" in out.stdout
