"""shard_map corpus-parallel cascade: sharded top-k ≡ single-device top-k.

Two layers:

- in-session tests on a ONE-device mesh (``shards=1``): the sharded code
  path — mesh construction, shard_map stage 0/1, round-robin lane
  permutation, cross-shard merge — runs end to end without multi-device
  XLA flags, and its results must be bit-for-bit the in-process cascade's.
- an 8-device identity sweep in a subprocess (the ``test_distributed.py``
  pattern: the host-platform device flag must never leak into the main
  session), covering ``search`` and ``search_batch``, several shard
  counts, and a mutated (delete/update/compact) corpus.
"""
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.index import SetStore, make_shard_context, search, search_batch

pytestmark = pytest.mark.sharded

REPO = Path(__file__).resolve().parent.parent
DIM = 8


def _corpus(n=120, seed=0):
    rng = np.random.default_rng(seed)
    store = SetStore(dim=DIM)
    store.add_many(
        [
            rng.normal(size=(int(rng.integers(3, 60)), DIM)).astype(np.float32)
            for _ in range(n)
        ]
    )
    return store, rng


class TestShardedSingleDevice:
    def test_shards1_bitwise_identity_search(self):
        store, rng = _corpus()
        for seed in range(3):
            q = np.random.default_rng(100 + seed).normal(size=(7, DIM)).astype(np.float32)
            a = search(q, store, 10)
            b = search(q, store, 10, shards=1)
            np.testing.assert_array_equal(a.ids, b.ids)
            np.testing.assert_array_equal(a.values, b.values)
            assert b.stats["shards"] == 1

    def test_shards1_bitwise_identity_search_batch(self):
        store, rng = _corpus(seed=1)
        qs = [
            rng.normal(size=(int(rng.integers(4, 12)), DIM)).astype(np.float32)
            for _ in range(4)
        ]
        for x, y in zip(
            search_batch(qs, store, 6), search_batch(qs, store, 6, shards=1)
        ):
            np.testing.assert_array_equal(x.ids, y.ids)
            np.testing.assert_array_equal(x.values, y.values)

    def test_shards1_on_mutated_store(self):
        store, rng = _corpus(seed=2)
        for sid in range(0, 120, 4):
            store.delete(sid)
        store.update(1, rng.normal(size=(25, DIM)).astype(np.float32))
        store.compact()
        q = rng.normal(size=(6, DIM)).astype(np.float32)
        a = search(q, store, 10)
        b = search(q, store, 10, shards=1)
        np.testing.assert_array_equal(a.ids, b.ids)
        np.testing.assert_array_equal(a.values, b.values)

    def test_directed_variant(self):
        store, rng = _corpus(seed=3, n=50)
        q = rng.normal(size=(6, DIM)).astype(np.float32)
        a = search(q, store, 5, variant="directed")
        b = search(q, store, 5, variant="directed", shards=1)
        np.testing.assert_array_equal(a.ids, b.ids)
        np.testing.assert_array_equal(a.values, b.values)

    def test_validation(self):
        store, rng = _corpus(seed=4, n=20)
        q = rng.normal(size=(4, DIM)).astype(np.float32)
        with pytest.raises(ValueError, match="anytime"):
            search(q, store, 3, shards=1, mode="anytime", epsilon=0.1)
        with pytest.raises(ValueError, match="exact"):
            search(q, store, 3, shards=1, method="exact")
        with pytest.raises(ValueError, match="exceeds"):
            search(q, store, 3, shards=4096)
        with pytest.raises(ValueError, match=">= 1"):
            make_shard_context(0)

    def test_shard_merge_span_emitted(self):
        from repro.obs import trace

        store, rng = _corpus(seed=5, n=60)
        q = rng.normal(size=(5, DIM)).astype(np.float32)
        with trace.capture() as get_events:
            search(q, store, 5, shards=1)
            events = get_events()
        spans = {e["name"]: e for e in events if e["type"] == "span"}
        assert "cascade.shard_merge" in spans, (
            "sharded search must emit the cascade.shard_merge span"
        )
        merge = spans["cascade.shard_merge"]
        assert merge["attrs"]["shards"] == 1
        assert merge["rid"] == spans["index.search"]["rid"]
        assert spans["cascade.stage0"]["attrs"]["shards"] == 1


# ---------------------------------------------------------------------------
# 8-device identity sweep (subprocess — the flag must not leak in-session)
# ---------------------------------------------------------------------------

CHECK = r"""
import jax, numpy as np
assert jax.device_count() == 8, jax.device_count()
from repro.index import SetStore, search, search_batch

rng = np.random.default_rng(0)
store = SetStore(dim=8)
store.add_many([
    rng.normal(size=(int(rng.integers(3, 60)), 8)).astype(np.float32)
    for _ in range(300)
])
qs = [rng.normal(size=(int(rng.integers(4, 16)), 8)).astype(np.float32)
      for _ in range(4)]

for q in qs:
    a = search(q, store, 10)
    for p in (2, 3, 8):
        b = search(q, store, 10, shards=p)
        assert np.array_equal(a.ids, b.ids), p
        assert np.array_equal(a.values, b.values), p

for x, y in zip(search_batch(qs, store, 10),
                search_batch(qs, store, 10, shards=8)):
    assert np.array_equal(x.ids, y.ids)
    assert np.array_equal(x.values, y.values)

# mutated corpus: delete 25%, update one, compact — identity must survive
for sid in range(0, 300, 4):
    store.delete(sid)
store.update(1, rng.normal(size=(33, 8)).astype(np.float32))
store.compact()
for q in qs[:2]:
    a = search(q, store, 10)
    b = search(q, store, 10, shards=8)
    assert np.array_equal(a.ids, b.ids)
    assert np.array_equal(a.values, b.values)
print("SHARDED-OK")
"""


@pytest.mark.slow
def test_sharded_cascade_8dev_identity():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run(
        [sys.executable, "-c", CHECK], env=env, capture_output=True, text=True,
        timeout=600,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "SHARDED-OK" in out.stdout
