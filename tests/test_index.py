"""repro.index: SetStore packing/summaries + certified cascade search.

The headline invariant is the certification: ``search()`` top-k ids and
values must be BIT-FOR-BIT identical to brute-force exact ranking, for any
corpus, any k (including ties and k ≥ corpus size), any padding layout.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import masked
from repro.core.exact import hausdorff_dense
from repro.hd import search as hd_search
from repro.index import (
    SetStore,
    bound_scale,
    bucket_capacity,
    certified_margins,
    direction_bank,
    interval_bounds,
    search,
    summarize_set,
)

KEY = jax.random.PRNGKey(0)

# Shared seeded generators (tests/strategies.py): same RandomState stream
# as the historical module-local copies, so every corpus is bit-identical.
from strategies import query_near as _query  # noqa: E402
from strategies import ragged_corpus as _corpus  # noqa: E402


# ---------------------------------------------------------------------------
# SetStore
# ---------------------------------------------------------------------------


def test_bucket_capacity_power_of_two():
    assert bucket_capacity(1) == 8            # min_bucket floor
    assert bucket_capacity(8) == 8
    assert bucket_capacity(9) == 16
    assert bucket_capacity(100) == 128
    assert bucket_capacity(3, min_bucket=2) == 4


def test_store_roundtrip_and_packing():
    sets, _ = _corpus(0)
    store = SetStore(dim=4)
    ids = store.add_many(sets)
    assert ids == list(range(len(sets)))
    assert store.n_sets == len(sets)
    assert store.total_points == sum(s.shape[0] for s in sets)
    for sid, pts in zip(ids, sets):
        np.testing.assert_array_equal(np.asarray(store.get(sid)), pts)
    # every set appears in exactly one bucket, with its padding masked off
    # and its sqnorms +inf-poisoned outside the valid rows
    seen = []
    for cap, bucket in store.packed_buckets().items():
        assert bucket.points.shape[1:] == (cap, 4)
        for row, sid in enumerate(bucket.set_ids):
            n = sets[sid].shape[0]
            assert cap >= n
            np.testing.assert_array_equal(
                np.asarray(bucket.points[row, :n]), sets[sid]
            )
            assert bool(jnp.all(bucket.valid[row, :n]))
            assert not bool(jnp.any(bucket.valid[row, n:]))
            assert bool(jnp.all(jnp.isinf(bucket.sqnorms[row, n:])))
            seen.append(int(sid))
    assert sorted(seen) == ids


def test_store_rejects_bad_sets():
    store = SetStore(dim=3)
    with pytest.raises(ValueError):
        store.add(np.zeros((0, 3), np.float32))     # empty set
    with pytest.raises(ValueError):
        store.add(np.zeros((4, 5), np.float32))     # wrong dim
    with pytest.raises(ValueError):
        search(np.zeros((4, 3), np.float32), store, 1)  # empty store


def test_summaries_match_numpy_reference():
    sets, _ = _corpus(1, n_sets=10)
    store = SetStore(dim=4)
    store.add_many(sets)
    sums = store.summaries()
    dirs = np.asarray(store.directions)
    for sid, pts in enumerate(sets):
        c = pts.mean(axis=0)
        r = np.linalg.norm(pts - c, axis=1)
        proj = pts @ dirs
        np.testing.assert_allclose(np.asarray(sums.centroid[sid]), c, atol=1e-5)
        np.testing.assert_allclose(float(sums.r_min[sid]), r.min(), atol=1e-5)
        np.testing.assert_allclose(float(sums.r_max[sid]), r.max(), atol=1e-5)
        np.testing.assert_allclose(
            np.asarray(sums.proj_lo[sid]), proj.min(axis=0), rtol=1e-4, atol=1e-4
        )
        np.testing.assert_allclose(
            np.asarray(sums.proj_hi[sid]), proj.max(axis=0), rtol=1e-4, atol=1e-4
        )
        assert int(sums.count[sid]) == pts.shape[0]


def test_summarize_is_padding_invariant():
    rng = np.random.RandomState(2)
    pts = rng.randn(11, 4).astype(np.float32)
    dirs = direction_bank(4, 2)
    raw, _ = summarize_set(jnp.asarray(pts), jnp.ones((11,), bool), dirs)
    padded = np.zeros((32, 4), np.float32)
    padded[:11] = pts
    # poison the padding with garbage: summaries must not see it
    padded[11:] = 1e9
    valid = np.zeros((32,), bool)
    valid[:11] = True
    masked_sum, sqn = summarize_set(jnp.asarray(padded), jnp.asarray(valid), dirs)
    for f_raw, f_masked in zip(raw, masked_sum):
        np.testing.assert_allclose(np.asarray(f_raw), np.asarray(f_masked), rtol=1e-6)
    assert bool(jnp.all(jnp.isinf(sqn[11:])))


# ---------------------------------------------------------------------------
# certified bounds
# ---------------------------------------------------------------------------


def test_interval_bounds_contain_true_hd():
    rng = np.random.RandomState(3)
    dirs = direction_bank(6, 3)
    # the 1e5 offset is the catastrophic-cancellation regime: projection
    # gaps of huge-coordinate clouds carry absolute fp32 error far larger
    # than any relative-in-the-gap margin — bound_scale must absorb it
    for trial, offset in [(t, o) for t in range(10) for o in (0.0, 1e5)]:
        a = (rng.randn(rng.randint(1, 30), 6) * rng.choice([0.3, 1.0, 5.0]) + offset).astype(np.float32)
        b = (rng.randn(rng.randint(1, 30), 6) + rng.randn(6) * 4 + offset).astype(np.float32)
        sa, _ = summarize_set(jnp.asarray(a), jnp.ones((a.shape[0],), bool), dirs)
        sb, _ = summarize_set(jnp.asarray(b), jnp.ones((b.shape[0],), bool), dirs)
        h = float(hausdorff_dense(a, b))
        scale = bound_scale(sa, sb)
        lb, ub = certified_margins(*interval_bounds(sa, sb), scale, 6)
        assert float(lb) <= h <= float(ub), (trial, offset, float(lb), h, float(ub))
        # directed bounds against directed truth
        from repro.core.exact import directed_hd_dense

        hd = float(directed_hd_dense(a, b))
        lbd, ubd = certified_margins(*interval_bounds(sa, sb, directed=True), scale, 6)
        assert float(lbd) <= hd <= float(ubd), (trial, offset, float(lbd), hd, float(ubd))


def test_masked_prohd_certificate_contains_truth_and_ignores_padding():
    rng = np.random.RandomState(4)
    a = rng.randn(13, 4).astype(np.float32)
    b = (rng.randn(9, 4) + 3.0).astype(np.float32)

    def padded(x, cap):
        p = np.full((cap, 4), 7.7e8, np.float32)  # garbage padding
        p[: x.shape[0]] = x
        v = np.zeros((cap,), bool)
        v[: x.shape[0]] = True
        return jnp.asarray(p), jnp.asarray(v)

    h = float(hausdorff_dense(a, b))
    certs = []
    for cap_a, cap_b in ((16, 16), (32, 64)):
        pa, va = padded(a, cap_a)
        pb, vb = padded(b, cap_b)
        cert = masked.masked_prohd_certified_jit(pa, va, pb, vb, alpha=0.2, m=2)
        assert float(cert.lower) <= h * (1 + 1e-5) + 1e-6
        assert h <= float(cert.upper) * (1 + 1e-5) + 1e-6
        assert float(cert.hd) <= h * (1 + 1e-5) + 1e-6  # full-inner: never over
        certs.append(cert)
    # the certificate is a function of the valid rows only — padding
    # layouts agree up to fp re-association (selection k's differ with
    # capacity, which may move hd; lower/upper are selection-free)
    np.testing.assert_allclose(float(certs[0].lower), float(certs[1].lower), rtol=2e-3)
    np.testing.assert_allclose(float(certs[0].upper), float(certs[1].upper), rtol=2e-3)


# ---------------------------------------------------------------------------
# cascade == brute force
# ---------------------------------------------------------------------------


def _assert_search_matches_bruteforce(sets, q, k, variant="hausdorff", min_bucket=8):
    store = SetStore(dim=q.shape[1], min_bucket=min_bucket)
    store.add_many(sets)
    res = search(q, store, k, variant=variant)
    ref = search(q, store, k, variant=variant, method="exact")
    np.testing.assert_array_equal(res.ids, ref.ids)
    np.testing.assert_array_equal(res.values, ref.values)
    assert res.stats["exact_refines"] <= ref.stats["exact_refines"]
    return res


def test_search_matches_bruteforce_with_duplicates_and_large_k():
    sets, rng = _corpus(5, n_sets=22, dup_every=3)
    q = _query(rng, sets, 4)
    _assert_search_matches_bruteforce(sets, q, 5)
    _assert_search_matches_bruteforce(sets, q, 100)   # k >= corpus size
    _assert_search_matches_bruteforce(sets, q, 5, variant="directed")


def test_search_is_padding_invariant():
    sets, rng = _corpus(6, n_sets=18)
    q = _query(rng, sets, 4)
    results = [
        _assert_search_matches_bruteforce(sets, q, 4, min_bucket=mb)
        for mb in (2, 8, 32)
    ]
    for r in results[1:]:
        np.testing.assert_array_equal(r.ids, results[0].ids)
        np.testing.assert_array_equal(r.values, results[0].values)


def test_search_prunes_separated_corpus():
    from repro.data.pointclouds import clustered_sets

    sets, _ = clustered_sets(
        jax.random.PRNGKey(7), 64, 4, sizes=(8, 16), n_clusters=8, spread=20.0
    )
    rng = np.random.RandomState(8)
    q = _query(rng, sets, 4)
    store = SetStore(dim=4)
    store.add_many(sets)
    res = search(q, store, 3)
    ref = search(q, store, 3, method="exact")
    np.testing.assert_array_equal(res.ids, ref.ids)
    np.testing.assert_array_equal(res.values, ref.values)
    assert res.stats["prune_fraction"] > 0.5
    assert res.stats["exact_refines"] < 32


def test_front_door_search_is_the_cascade():
    sets, rng = _corpus(9, n_sets=12)
    q = _query(rng, sets, 4)
    store = SetStore(dim=4)
    store.add_many(sets)
    res = hd_search(q, store, 3, measure=True)
    ref = search(q, store, 3)
    np.testing.assert_array_equal(res.ids, ref.ids)
    np.testing.assert_array_equal(res.values, ref.values)
    assert res.meta.variant == "hausdorff"
    assert res.meta.method == "cascade"
    assert res.meta.elapsed_s is not None
    assert {"candidates_scanned", "exact_refines", "prune_fraction"} <= set(res.stats)


def test_search_validates_axes():
    sets, rng = _corpus(10, n_sets=4)
    store = SetStore(dim=4)
    store.add_many(sets)
    q = _query(rng, sets, 4)
    with pytest.raises(ValueError):
        search(q, store, 1, variant="chamfer")
    with pytest.raises(ValueError):
        search(q, store, 1, method="prohd")
    with pytest.raises(ValueError):
        search(q, store, -1)            # k=0 is now a valid empty request
    with pytest.raises(ValueError):
        search(q, store, 1, stage2="vectorized")
    with pytest.raises(ValueError):
        search(q[:, :2], store, 1)


def test_search_matches_bruteforce_on_large_magnitude_corpus():
    # coordinates ~1e5: certification must survive fp32 cancellation in
    # every stage's bounds (regression for the scale-aware margins)
    sets, rng = _corpus(14, n_sets=20, dup_every=4)
    sets = [s + np.float32(1e5) for s in sets]
    q = _query(rng, sets, 4)
    _assert_search_matches_bruteforce(sets, q, 4)


def test_interleaved_add_search_repacks_only_the_touched_bucket():
    sets, rng = _corpus(15, n_sets=12, max_n=7)   # all land in the 8-bucket
    store = SetStore(dim=4)
    store.add_many(sets)
    q = _query(rng, sets, 4)
    search(q, store, 2)
    before = store.packed_buckets()
    store.add(np.zeros((30, 4), np.float32) + 50.0)  # lands in the 32-bucket
    res = search(q, store, 2)
    after = store.packed_buckets()
    # the untouched 8-bucket's device arrays were reused, not re-stacked
    assert after[8].points is before[8].points
    assert set(after) == {8, 32}
    ref = search(q, store, 2, method="exact")
    np.testing.assert_array_equal(res.ids, ref.ids)
    np.testing.assert_array_equal(res.values, ref.values)


def test_masked_projected_hd_empty_target_side_is_zero():
    pa = jnp.asarray(np.random.RandomState(0).randn(6, 2), jnp.float32)
    va = jnp.ones((6,), bool)
    pb = jnp.full((4, 2), 123.0, jnp.float32)
    vb = jnp.zeros((4,), bool)  # no valid targets at all
    assert float(masked.masked_projected_hd(pa, va, pb, vb, directed=True)) == 0.0


# Deterministic sweep of the same property the hypothesis module
# (tests/test_index_properties.py) hunts adversarially — keeps the
# certification exercised even where hypothesis is not installed.
@pytest.mark.parametrize("seed,k,dup_every", [(11, 1, 0), (12, 3, 3), (13, 1000, 2)])
def test_cascade_identical_to_bruteforce_seeded(seed, k, dup_every):
    sets, rng = _corpus(seed, n_sets=16, d=4, max_n=14, dup_every=dup_every)
    q = _query(rng, sets, 4)
    _assert_search_matches_bruteforce(sets, q, k)


# ---------------------------------------------------------------------------
# batched stage 2 (PR 4 tentpole)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed,k,variant", [(21, 3, "hausdorff"), (22, 1000, "hausdorff"), (23, 4, "directed")])
def test_stage2_batched_and_sequential_identical(seed, k, variant):
    """Both stage-2 dispatch modes return the SAME BITS as brute force —
    batching tightens bounds, it never touches a returned value."""
    sets, rng = _corpus(seed, n_sets=20, d=4, max_n=18, dup_every=4)
    q = _query(rng, sets, 4)
    store = SetStore(dim=4)
    store.add_many(sets)
    bat = search(q, store, k, variant=variant, stage2="batched")
    seq = search(q, store, k, variant=variant, stage2="sequential")
    ref = search(q, store, k, variant=variant, method="exact")
    for res in (bat, seq):
        np.testing.assert_array_equal(res.ids, ref.ids)
        np.testing.assert_array_equal(res.values, ref.values)
    assert bat.stats["stage2_mode"] == "batched"
    assert seq.stats["stage2_mode"] == "sequential"
    # one dispatch per frontier candidate sequentially…
    assert seq.stats["stage2_calls"] == seq.stats["exact_refines"]
    # …while batching only ever raw-refines a subset of that frontier
    assert bat.stats["exact_refines"] <= seq.stats["exact_refines"]


def test_stage2_batched_raw_refines_only_the_boundary():
    """On an overlapping corpus (stage 0/1 can barely prune, so the whole
    corpus reaches stage 2) the batched mode measures the ENTIRE frontier
    in O(buckets) jitted calls and raw-refines only the ≈ k candidates
    whose ±fp_margin intervals straddle the top-k boundary — while the
    sequential mode pays one dispatch per candidate it inspects."""
    sets, rng = _corpus(24, n_sets=60, d=8, max_n=25, n_clusters=1, spread=0.5)
    q = _query(rng, sets, 8)
    store = SetStore(dim=8)
    store.add_many(sets)
    k = 3
    bat = search(q, store, k, stage2="batched")
    seq = search(q, store, k, stage2="sequential")
    ref = search(q, store, k, method="exact")
    for res in (bat, seq):
        np.testing.assert_array_equal(res.ids, ref.ids)
        np.testing.assert_array_equal(res.values, ref.values)
    # the overlapping regime floods stage 2a with (almost) the whole corpus…
    assert bat.stats["stage2_batched_candidates"] > 3 * k
    # …which the batched pass absorbs in O(buckets) calls, leaving only the
    # boundary for raw per-candidate dispatch — never more than sequential
    assert bat.stats["exact_refines"] <= k + 2
    assert bat.stats["exact_refines"] <= seq.stats["exact_refines"]
    assert seq.stats["exact_refines"] > k
    n_buckets = len(store.bucket_capacities)
    assert bat.stats["stage2_calls"] <= 2 * n_buckets + bat.stats["exact_refines"]
    assert bat.stats["stage2_distinct_shapes"] <= n_buckets + bat.stats["exact_refines"]


def test_slot_index_tracks_packed_buckets():
    sets, _ = _corpus(25, n_sets=12)
    store = SetStore(dim=4)
    store.add_many(sets)
    slot = store.slot_index()
    buckets = store.packed_buckets()
    assert sorted(slot) == list(range(len(sets)))
    for sid, (cap, row) in slot.items():
        assert int(buckets[cap].set_ids[row]) == sid
    # grows with the store, including for fresh capacities
    sid = store.add(np.full((40, 4), 3.0, np.float32))
    cap, row = store.slot_index()[sid]
    assert cap == 64 and int(store.packed_buckets()[64].set_ids[row]) == sid


def test_slot_index_unknown_ids_are_absent():
    """slot_index() is a plain {known id: slot} dict — ids never stored
    (including negative and past-the-end ones) are ABSENT, so a stale or
    corrupted id raises KeyError instead of silently aliasing a slab row."""
    sets, _ = _corpus(26, n_sets=5)
    store = SetStore(dim=4)
    store.add_many(sets)
    slot = store.slot_index()
    for bogus in (-1, len(sets), len(sets) + 7, 10**6):
        assert bogus not in slot
        with pytest.raises(KeyError):
            slot[bogus]
    # the index is a snapshot: mutating the returned dict must not corrupt
    # the store's cached copy
    slot[-1] = (999, 0)
    assert -1 not in store.slot_index()


def test_search_on_empty_store_raises():
    store = SetStore(dim=4)
    q = np.zeros((3, 4), np.float32)
    with pytest.raises(ValueError, match="empty SetStore"):
        search(q, store, 1)
    # k == 0 is the one degenerate request served without a corpus scan —
    # but an empty store still has nothing to serve it from
    with pytest.raises(ValueError, match="empty SetStore"):
        search(q, store, 0)


def test_single_all_padded_slab_lane_conventions():
    """A bucket whose ONE slab lane is entirely padding (no valid row) —
    the store itself can never produce it (empty sets are rejected), but
    batched consumers can meet it via degenerate gathers.  Every backend
    must finalize it by the empty-side conventions, not garbage."""
    pts = jnp.asarray(np.full((1, 8, 3), 7.7e8, np.float32))  # garbage fill
    valid = jnp.zeros((1, 8), bool)
    q = jnp.asarray(np.random.RandomState(0).randn(5, 3).astype(np.float32))
    for be in sorted(masked.EXACT_MASKED_BACKENDS):
        vals = np.asarray(
            masked.masked_exact_hd_batched(
                q, pts, valid_slab=valid, directed=True, backend=be,
                block_a=64, block_b=64,
            )
        )
        assert vals.shape == (1,) and np.isinf(vals[0]), be  # empty target
        undirected = np.asarray(
            masked.masked_exact_hd_batched(
                q, pts, valid_slab=valid, backend=be, block_a=64, block_b=64
            )
        )
        assert np.isinf(undirected[0]), be


# ---------------------------------------------------------------------------
# direction banks (satellite: data-driven banks)
# ---------------------------------------------------------------------------


from strategies import anisotropic_corpus as _anisotropic_corpus  # noqa: E402


def test_direction_bank_orthonormal_and_deterministic():
    key = jax.random.PRNGKey(5)
    for bank in (
        direction_bank(16, 4, key=key),
        direction_bank(16, 4, data=jnp.asarray(np.random.RandomState(1).randn(64, 16), jnp.float32)),
        direction_bank(3, 7),     # m > d clamps to d
    ):
        b = np.asarray(bank)
        assert b.shape[0] in (16, 3) and b.shape[1] <= b.shape[0]
        np.testing.assert_allclose(b.T @ b, np.eye(b.shape[1]), atol=1e-5)
    # deterministic: same seed → same bits; different seed → different bank
    np.testing.assert_array_equal(
        np.asarray(direction_bank(16, 4, key=key)),
        np.asarray(direction_bank(16, 4, key=jax.random.PRNGKey(5))),
    )
    assert not np.array_equal(
        np.asarray(direction_bank(16, 4, key=key)),
        np.asarray(direction_bank(16, 4, key=jax.random.PRNGKey(6))),
    )
    data = jnp.asarray(np.random.RandomState(2).randn(128, 16), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(direction_bank(16, 4, data=data)),
        np.asarray(direction_bank(16, 4, data=data)),
    )


def test_data_driven_bank_tightens_stage0_lower_bounds():
    """On an anisotropic corpus, PCA directions capture the separation axis
    a random bank mostly misses — stage-0 interval-gap lower bounds must
    come out strictly tighter (ROADMAP: 'nothing refits yet')."""
    sets, rng = _anisotropic_corpus(30)
    q = (np.asarray(sets[0]) + 0.0).astype(np.float32)
    sample = np.concatenate(sets)

    def stage0_lbs(directions):
        store = SetStore(dim=16, directions=directions)
        store.add_many(sets)
        qsum = store.summarize(q)
        lb, _ = interval_bounds(qsum, store.summaries())
        return np.asarray(lb, np.float64)

    lb_rand = stage0_lbs(direction_bank(16, 4, key=jax.random.PRNGKey(0)))
    lb_pca = stage0_lbs(direction_bank(16, 4, data=jnp.asarray(sample)))
    # sound either way (never above the true distance)…
    for sid, pts in enumerate(sets):
        h = float(hausdorff_dense(jnp.asarray(q), jnp.asarray(pts)))
        assert lb_pca[sid] <= h + 1e-3 and lb_rand[sid] <= h + 1e-3
    # …but the data-driven bank is decisively tighter in aggregate
    assert lb_pca.mean() > 1.5 * lb_rand.mean()
    # …and the cascade stays brute-force-identical under a data-driven bank
    store = SetStore(dim=16, directions=direction_bank(16, 4, data=jnp.asarray(sample)))
    store.add_many(sets)
    q2 = _query(rng, sets, 16)
    res = search(q2, store, 3)
    ref = search(q2, store, 3, method="exact")
    np.testing.assert_array_equal(res.ids, ref.ids)
    np.testing.assert_array_equal(res.values, ref.values)
