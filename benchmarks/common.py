"""Shared benchmark utilities: timing, dataset builders, method registry.

CPU-scaled sizes: the paper benches up to 2M×256 on a 64-core node; this
container has 6 cores, so default sizes are scaled down (documented per
table in EXPERIMENTS.md) while keeping every RATIO the paper reports
(ProHD-vs-sampling error, speedup-vs-exact) measurable.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.exact import hausdorff_tiled
from repro.core.prohd import ProHDConfig
from repro.data.pointclouds import make_dataset
from repro.hd import HDConfig, set_distance

KEY = jax.random.PRNGKey(20250717)


def timed(fn, *args, warmup: int = 1, iters: int = 2, **kw):
    """Median wall time (s) + last result, fully blocking."""
    for _ in range(warmup):
        res = fn(*args, **kw)
        jax.block_until_ready(res)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        res = fn(*args, **kw)
        jax.block_until_ready(res)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2], res


def timed_once(fn, *args, **kw):
    """Two-call timing for expensive exact baselines: the first call pays
    compile, the SECOND call's time is reported — so speedup claims never
    benefit from the baseline's compile time."""
    res = fn(*args, **kw)
    jax.block_until_ready(res)
    t0 = time.perf_counter()
    res = fn(*args, **kw)
    jax.block_until_ready(res)
    return time.perf_counter() - t0, res


def rel_err(approx: float, exact: float) -> float:
    return abs(approx - exact) / max(exact, 1e-12) * 100.0


def exact_hd(a, b) -> float:
    return float(hausdorff_tiled(a, b, block=4096))


def run_method(name: str, a, b, alpha: float, key=KEY, **kw):
    """Dispatch one approximate method via the repro.hd front door;
    returns (hd, subset_size).  The benches therefore measure exactly what
    production callers run (dispatch overhead is gated < 5% by the
    ``dispatch`` bench, so the figures stay comparable across PRs)."""
    if name in ("prohd", "prohd_subset"):
        inner = {"prohd": "full", "prohd_subset": "subset"}[name]
        res = set_distance(
            a, b, method="prohd", backend="tiled",
            config=HDConfig(prohd=ProHDConfig(alpha=alpha, inner=inner, **kw)),
        )
        return float(res.value), int(res.stats["n_sel_a"]) + int(res.stats["n_sel_b"])
    if name in ("random", "systematic"):
        res = set_distance(
            a, b, method="sampling", backend="tiled", key=key,
            config=HDConfig(alpha=alpha, sampler=name),
        )
        return float(res.value), int(res.stats["n_sampled"])
    raise KeyError(name)


def dataset(name: str, n_a: int, n_b: int, d: int, seed: int = 0):
    return make_dataset(name, jax.random.fold_in(KEY, seed), n_a, n_b, d)


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
