"""Benchmark entry point (deliverable d): one function per paper table.

Prints ``name,us_per_call,derived`` CSV rows, then a findings summary.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig1,...]
                                            [--json [PATH]]

``--json`` additionally persists every row (plus environment metadata) to a
machine-readable JSON file — ``BENCH_PR1.json`` by default — so the perf
trajectory of the repo is diffable across PRs.
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import time

DEFAULT_JSON = "BENCH_PR1.json"

# Version of the --json payload's structure (meta/rows/findings + the
# host fingerprint).  Bump on any change a cross-PR diff tool would have
# to branch on.
BENCH_SCHEMA_VERSION = 2


def _parse_row(row: str) -> dict:
    name, us, derived = row.split(",", 2)
    return {"name": name, "us_per_call": float(us), "derived": derived}


def _host_fingerprint(jax) -> dict:
    """Where these numbers came from: two runs with different fingerprints
    are not directly comparable and a diff tool should say so."""
    dev = jax.devices()[0]
    return {
        "platform": platform.platform(),
        "machine": platform.machine(),
        "python": platform.python_version(),
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "device_kind": getattr(dev, "device_kind", str(dev)),
        "device_count": jax.device_count(),
    }


def _git_rev() -> str:
    import subprocess

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10, cwd=repo,
        )
        rev = out.stdout.strip()
        if out.returncode == 0 and rev:
            dirty = subprocess.run(
                ["git", "status", "--porcelain"],
                capture_output=True, text=True, timeout=10, cwd=repo,
            )
            return rev + ("-dirty" if dirty.stdout.strip() else "")
    except (OSError, subprocess.SubprocessError):
        pass
    return "unknown"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--only",
        default=None,
        help="comma list: fig1,table2,fig2,fig3,fig4,fig5,phases,backends,fused,dispatch,index,index_stage2,bucket_kernel,reliability,multiquery,obs,anytime,sharded",
    )
    ap.add_argument(
        "--quick", action="store_true", help="fig1 + phases + fused only"
    )
    ap.add_argument(
        "--json",
        nargs="?",
        const=DEFAULT_JSON,
        default=None,
        metavar="PATH",
        help=f"also write rows as JSON (default path: {DEFAULT_JSON})",
    )
    args = ap.parse_args()

    import jax

    from benchmarks import tables

    benches = {
        "fig1": tables.fig1_overall_effectiveness,
        "table2": tables.table2_sample_efficiency,
        "fig2": tables.fig2_param_sensitivity,
        "fig3": tables.fig3_dim_scalability,
        "fig4": tables.fig4_ratio_scalability,
        "fig5": tables.fig5_size_scalability,
        "phases": tables.bench_prohd_phases,
        "backends": tables.bench_backends,
        "fused": tables.bench_fused_vs_twosweep,
        "dispatch": tables.bench_dispatch_overhead,
        "index": tables.bench_index,
        "index_stage2": tables.bench_index_stage2,
        "bucket_kernel": tables.bench_bucket_kernel,
        "reliability": tables.bench_reliability,
        "multiquery": tables.bench_multiquery,
        "obs": tables.bench_obs,
        "anytime": tables.bench_anytime,
        "sharded": tables.bench_sharded,
    }
    if args.quick:
        selected = ["fig1", "phases", "fused"]
    elif args.only:
        selected = [s.strip() for s in args.only.split(",")]
    else:
        # "backends" already embeds the fused comparison; skip the
        # standalone entry so a full run doesn't execute it twice.
        selected = [n for n in benches if n != "fused"]

    all_rows: list[str] = []
    print("name,us_per_call,derived")
    for name in selected:
        t0 = time.time()
        try:
            for row in benches[name]():
                print(row, flush=True)
                all_rows.append(row)
        except Exception as e:  # noqa: BLE001
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}", flush=True)
            raise
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)

    if tables.REPORT:
        print("\n# ==== findings ====")
        for line in tables.REPORT:
            print(f"# {line}")

    if args.json:
        payload = {
            "meta": {
                "schema": BENCH_SCHEMA_VERSION,
                "benches": selected,
                "host": _host_fingerprint(jax),
                "git_rev": _git_rev(),
                "unix_time": int(time.time()),
            },
            "rows": [_parse_row(r) for r in all_rows],
            "findings": list(tables.REPORT),
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        print(f"# wrote {len(payload['rows'])} rows to {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
