"""Benchmark entry point (deliverable d): one function per paper table.

Prints ``name,us_per_call,derived`` CSV rows, then a findings summary.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig1,...]
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma list: fig1,table2,fig2,fig3,fig4,fig5,phases")
    ap.add_argument("--quick", action="store_true", help="fig1 + phases only")
    args = ap.parse_args()

    from benchmarks import tables

    benches = {
        "fig1": tables.fig1_overall_effectiveness,
        "table2": tables.table2_sample_efficiency,
        "fig2": tables.fig2_param_sensitivity,
        "fig3": tables.fig3_dim_scalability,
        "fig4": tables.fig4_ratio_scalability,
        "fig5": tables.fig5_size_scalability,
        "phases": tables.bench_prohd_phases,
        "backends": tables.bench_backends,
    }
    if args.quick:
        selected = ["fig1", "phases"]
    elif args.only:
        selected = [s.strip() for s in args.only.split(",")]
    else:
        selected = list(benches)

    print("name,us_per_call,derived")
    for name in selected:
        t0 = time.time()
        try:
            for row in benches[name]():
                print(row, flush=True)
        except Exception as e:  # noqa: BLE001
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}", flush=True)
            raise
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)

    if tables.REPORT:
        print("\n# ==== findings ====")
        for line in tables.REPORT:
            print(f"# {line}")


if __name__ == "__main__":
    main()
