"""One function per paper table/figure (deliverable d).

Each returns a list of CSV rows "name,us_per_call,derived" and appends
human-readable findings to the shared REPORT list consumed by run.py.
"""
from __future__ import annotations

import jax

from benchmarks.common import (
    KEY,
    csv_row,
    dataset,
    exact_hd,
    rel_err,
    run_method,
    timed,
    timed_once,
)

REPORT: list[str] = []

DATASETS = [
    # (name, d, n_a, n_b) — CPU-scaled versions of the paper's Fig. 1 sets
    ("image", 64, 12000, 12000),     # CIFAR/MNIST-PCA proxy
    ("higgs", 28, 20000, 20000),
    ("random", 16, 20000, 20000),
]


def fig1_overall_effectiveness(alpha: float = 0.01) -> list[str]:
    """Fig. 1: relative error vs runtime, all methods, three datasets."""
    rows = []
    for dname, d, n_a, n_b in DATASETS:
        a, b = dataset(dname, n_a, n_b, d)
        t_exact, h_exact = timed_once(lambda: exact_hd(a, b))
        h_exact = float(h_exact)
        rows.append(csv_row(f"fig1/{dname}/exact_ann", t_exact * 1e6, "err_pct=0.0"))
        for method in ("prohd", "prohd_subset", "random", "systematic"):
            t, (hd, nsel) = timed(lambda m=method: run_method(m, a, b, alpha))
            err = rel_err(hd, h_exact)
            rows.append(
                csv_row(f"fig1/{dname}/{method}", t * 1e6,
                        f"err_pct={err:.3f};subset={nsel};speedup={t_exact/t:.1f}x")
            )
            if method == "prohd":
                REPORT.append(
                    f"fig1 {dname}: ProHD err={err:.3f}% speedup={t_exact/t:.1f}x"
                )
    return rows


def table2_sample_efficiency() -> list[str]:
    """Table II: subset size sampling needs to match ProHD accuracy."""
    rows = []
    for dname, d, n_a, n_b in DATASETS:
        a, b = dataset(dname, n_a, n_b, d)
        h_exact = exact_hd(a, b)
        hd_p, n_p = run_method("prohd", a, b, 0.01)
        target = rel_err(hd_p, h_exact)
        for method in ("random", "systematic"):
            # double alpha until the method matches ProHD's error (3-seed avg)
            alpha, matched = 0.01, None
            while alpha < 0.35:
                errs = [
                    rel_err(run_method(method, a, b, alpha, key=jax.random.fold_in(KEY, s))[0], h_exact)
                    for s in range(3)
                ]
                err = sum(errs) / len(errs)
                if err <= target + 1e-9:
                    matched = alpha
                    break
                alpha *= 2
            n_needed = run_method(method, a, b, matched or 0.35)[1]
            ratio = n_needed / max(n_p, 1)
            rows.append(
                csv_row(f"table2/{dname}/{method}", 0.0,
                        f"prohd_n={n_p};prohd_err={target:.3f};needed_n={n_needed};ratio={ratio:.2f}")
            )
            REPORT.append(
                f"table2 {dname}: {method} needs {ratio:.1f}x ProHD's subset to match {target:.2f}% err"
            )
    return rows


def fig2_param_sensitivity() -> list[str]:
    """Fig. 2: error + runtime vs selection fraction α (image & higgs)."""
    rows = []
    for dname, d, n_a, n_b in [("image", 64, 12000, 12000), ("higgs", 28, 20000, 20000)]:
        a, b = dataset(dname, n_a, n_b, d)
        h_exact = exact_hd(a, b)
        for alpha in (0.005, 0.01, 0.02, 0.05, 0.10, 0.20):
            for method in ("prohd", "random"):
                t, (hd, nsel) = timed(lambda m=method, al=alpha: run_method(m, a, b, al))
                rows.append(
                    csv_row(f"fig2/{dname}/{method}/alpha{alpha}", t * 1e6,
                            f"err_pct={rel_err(hd, h_exact):.3f};subset={nsel}")
                )
    return rows


def fig3_dim_scalability() -> list[str]:
    """Fig. 3: error + runtime vs D (α=0.01)."""
    rows = []
    for dname in ("image", "random"):
        for d in (2, 4, 8, 16, 32, 64, 128, 256):
            a, b = dataset(dname, 12000, 12000, d, seed=d)
            h_exact = exact_hd(a, b)
            for method in ("prohd", "random"):
                t, (hd, _) = timed(lambda m=method: run_method(m, a, b, 0.01))
                rows.append(
                    csv_row(f"fig3/{dname}/{method}/D{d}", t * 1e6,
                            f"err_pct={rel_err(hd, h_exact):.3f}")
                )
    return rows


def fig4_ratio_scalability() -> list[str]:
    """Fig. 4: error vs size ratio n_b/n_a (higgs D=28, random D=4)."""
    rows = []
    for dname, d in (("higgs", 28), ("random", 4)):
        n_a = 24000
        for ratio in (0.125, 0.25, 0.5, 1.0):
            n_b = int(n_a * ratio)
            a, b = dataset(dname, n_a, n_b, d, seed=int(ratio * 100))
            h_exact = exact_hd(a, b)
            for method in ("prohd", "random"):
                t, (hd, _) = timed(lambda m=method: run_method(m, a, b, 0.01))
                rows.append(
                    csv_row(f"fig4/{dname}/{method}/ratio{ratio}", t * 1e6,
                            f"err_pct={rel_err(hd, h_exact):.3f}")
                )
    return rows


def fig5_size_scalability() -> list[str]:
    """Fig. 5: error + runtime vs total points (higgs D=28, random D=4).

    Exact ground truth up to 160k total (CPU budget); above that ProHD
    runtime-only (the paper's 2M point shows linear scaling — we measure
    the same slope).
    """
    rows = []
    for dname, d in (("higgs", 28), ("random", 4)):
        for n in (5000, 10000, 20000, 40000):
            a, b = dataset(dname, n, n, d, seed=n % 997)
            h_exact = exact_hd(a, b)
            for method in ("prohd", "random"):
                t, (hd, _) = timed(lambda m=method: run_method(m, a, b, 0.01))
                rows.append(
                    csv_row(f"fig5/{dname}/{method}/n{2*n}", t * 1e6,
                            f"err_pct={rel_err(hd, h_exact):.3f}")
                )
        # approx-only scaling points (no exact baseline at this size on CPU)
        for n in (100000, 250000):
            a, b = dataset(dname, n, n, d, seed=n % 997)
            t, (hd, nsel) = timed(lambda: run_method("prohd", a, b, 0.01), iters=1)
            rows.append(csv_row(f"fig5/{dname}/prohd_only/n{2*n}", t * 1e6,
                                f"hd={hd:.5f};subset={nsel}"))
    return rows


def bench_prohd_phases() -> list[str]:
    """Phase breakdown (complexity §II-D): projection vs selection vs ANN."""
    import jax.numpy as jnp

    from repro.core import ProHDConfig
    from repro.core.projections import direction_set
    from repro.core.prohd import prohd_masks
    from repro.core.selection import selection_capacity, take_selected

    a, b = dataset("higgs", 50000, 50000, 28)
    cfg = ProHDConfig(alpha=0.01)
    m = cfg.resolve_m(28)
    t_dirs, dirs = timed(lambda: direction_set(a, b, m))
    t_sel, sel = timed(lambda: prohd_masks(a, b, cfg))
    cap = selection_capacity(50000, m, 0.01)
    a_sel, va = take_selected(a, sel.mask_a, cap)
    b_sel, vb = take_selected(b, sel.mask_b, cap)
    from repro.core.exact import directed_hd_tiled

    t_ann, _ = timed(
        lambda: jnp.maximum(
            directed_hd_tiled(a_sel, b, valid_a=va),
            directed_hd_tiled(b_sel, a, valid_a=vb),
        )
    )
    rows = [
        csv_row("phases/directions", t_dirs * 1e6, "centroid+pca"),
        csv_row("phases/selection", (t_sel - t_dirs) * 1e6, "topk+masks"),
        csv_row("phases/ann", t_ann * 1e6, "queries-vs-full"),
    ]
    REPORT.append(
        f"phases (50k,50k,D=28): dirs={t_dirs*1e3:.0f}ms sel={max(t_sel-t_dirs,0)*1e3:.0f}ms ann={t_ann*1e3:.0f}ms"
    )
    return rows


def bench_backends() -> list[str]:
    """Paper-faithful vs beyond-paper algorithm backends (§Perf cell 0).

    - PCA: rsvd (paper's randomized SVD, O(nDm)) vs gram (TPU-native
      O(nD²) matmul + eigh) vs subspace iteration.
    - inner mode: full (certified) vs subset (literal Alg. 3).
    - fused vs two-sweep undirected HD, and projection pruning (PR 1).
    """
    import jax

    from repro.core.prohd import ProHDConfig, prohd

    rows = []
    a, b = dataset("higgs", 50000, 50000, 28)
    h_exact = exact_hd(a, b)
    key = jax.random.PRNGKey(0)
    for pca in ("rsvd", "gram", "subspace"):
        t, est = timed(lambda p=pca: prohd(a, b, ProHDConfig(alpha=0.01, pca_method=p), key=key))
        err = rel_err(float(est.hd), h_exact)
        rows.append(csv_row(f"backends/pca_{pca}", t * 1e6, f"err_pct={err:.3f}"))
        REPORT.append(f"backends: pca={pca} t={t*1e3:.0f}ms err={err:.3f}%")
    for inner in ("full", "subset"):
        t, est = timed(lambda i=inner: prohd(a, b, ProHDConfig(alpha=0.01, inner=i)))
        err = rel_err(float(est.hd), h_exact)
        over = float(est.hd) > h_exact * (1 + 1e-6)
        rows.append(csv_row(f"backends/inner_{inner}", t * 1e6,
                            f"err_pct={err:.3f};overestimates={over}"))
    rows += bench_fused_vs_twosweep()
    return rows


def bench_fused_vs_twosweep() -> list[str]:
    """PR 1 tentpole: one fused bidirectional d² pass vs two directed sweeps.

    The primary comparison is structurally identical on both sides: the
    baseline's directed scan does full-row (n × block_b) GEMMs, so the
    fused run uses block_a = n and the SAME block_b — the only difference
    is fusion (each Gram tile computed once, reduced in both directions),
    so the speedup is attributable to the kernel change.  Near 2× on
    GEMM-bound shapes.  The pruned rows additionally change the block
    size (pruning needs finer tiles to find gaps) — their blocks are
    recorded in the derived field so the trajectory stays interpretable.
    Pruning is measured on overlapping pairs and on a separated
    (drift-style) pair where it actually bites.
    """
    import jax
    import jax.numpy as jnp

    # Direct kernel-level entry points on purpose: this bench compares the
    # fused vs two-sweep FORMULATIONS, so neither side should carry the
    # front door's (or the compat shim's) dispatch on top.
    from repro.core.exact import hausdorff_fused_tiled, hausdorff_twosweep_tiled
    from repro.core.projections import direction_set
    from repro.core.tile_bounds import order_by_projection

    P_BLK = 512  # pruned-variant tile size

    def one_pair(tag, a, b, n, d, block):
        t2, h2 = timed(lambda: hausdorff_twosweep_tiled(a, b, block=block))
        tf, hf = timed(lambda: hausdorff_fused_tiled(a, b, block_a=n, block_b=block))
        dirs = direction_set(a, b, 4)
        pa = jnp.matmul(a, dirs, preferred_element_type=jnp.float32)
        pb = jnp.matmul(b, dirs, preferred_element_type=jnp.float32)
        a_s, pa_s, _, _ = order_by_projection(a, pa)
        b_s, pb_s, _, _ = order_by_projection(b, pb)
        tp, hp = timed(lambda: hausdorff_fused_tiled(
            a_s, b_s, block_a=P_BLK, block_b=P_BLK, prune_projs=(pa_s, pb_s)))
        rows = [
            csv_row(f"fused/{tag}/twosweep", t2 * 1e6,
                    f"hd={float(h2):.5f};block={block}"),
            csv_row(f"fused/{tag}/fused", tf * 1e6,
                    f"hd={float(hf):.5f};speedup_vs_twosweep={t2/tf:.2f}x;"
                    f"block_a={n};block_b={block}"),
            csv_row(f"fused/{tag}/fused_pruned", tp * 1e6,
                    f"hd={float(hp):.5f};speedup_vs_twosweep={t2/tp:.2f}x;"
                    f"block_a={P_BLK};block_b={P_BLK}"),
        ]
        REPORT.append(
            f"fused {tag} ({n}x{n},D={d}): fused {t2/tf:.2f}x, "
            f"fused+pruned {t2/tp:.2f}x vs two sweeps"
        )
        return rows

    rows = []
    for dname, d, n, block in (("higgs", 28, 20000, 2048), ("image", 64, 12000, 2048)):
        a, b = dataset(dname, n, n, d)
        rows += one_pair(dname, a, b, n, d, block)

    # drift-style separated pair: where projection pruning actually bites
    key = jax.random.PRNGKey(11)
    n, d = 20000, 16
    a = jax.random.normal(key, (n, d), jnp.float32)
    b = jax.random.normal(jax.random.fold_in(key, 1), (n, d), jnp.float32) + 2.0
    rows += one_pair("shifted", a, b, n, d, 2048)
    return rows


def bench_index(n_sets: int = 5000, d: int = 16, k: int = 10) -> list[str]:
    """PR 3 tentpole: certified bound-cascade retrieval vs corpus brute force.

    A separated-clusters corpus (the paper's vector-DB regime) of
    ``n_sets`` ragged sets; one HD-k-NN query served two ways through the
    same machinery:

    - ``cascade``  — repro.hd.search (summary bounds → vmapped bucketed
      masked ProHD → exact refinement of the frontier);
    - ``bruteforce`` — the same search with method="exact" (every set
      refined), which is the reference the cascade must match.

    The derived fields carry the contract ``scripts/check.sh`` gates on:
    ``identical`` (top-k ids AND values bit-for-bit equal),
    ``exact_refines`` vs ``candidates`` (< 50% required), and
    ``prune_fraction`` (> 0.5 required on this corpus).
    """
    import numpy as np

    from repro.data.pointclouds import clustered_sets
    from repro.hd import search
    from repro.index import SetStore

    key = jax.random.fold_in(KEY, 3141)
    sets, labels = clustered_sets(key, n_sets, d, sizes=(64, 128, 256))

    import time as _time

    t0 = _time.perf_counter()
    store = SetStore(dim=d)
    store.add_many(sets)
    # summaries()/packed_buckets() are lazy; materialize them inside the
    # build measurement so the search rows time searching, not packing.
    store.summaries()
    store.packed_buckets()
    t_build = _time.perf_counter() - t0

    # query: a fresh blob near set 0's cluster (so a real neighbourhood
    # exists), never equal to any stored set
    qrng = np.random.RandomState(7)
    q = np.asarray(sets[0]).mean(axis=0) + qrng.randn(128, d).astype(np.float32) * 0.5

    t_cas, res = timed(lambda: search(q, store, k), iters=3)
    t_bru, ref = timed_once(lambda: search(q, store, k, method="exact"))

    identical = bool(
        np.array_equal(res.ids, ref.ids) and np.array_equal(res.values, ref.values)
    )
    s = res.stats
    rows = [
        csv_row(
            "index/build", t_build * 1e6,
            f"n_sets={n_sets};points={store.total_points};d={d};"
            # |-joined: derived must stay comma-free (3-column CSV contract)
            f"buckets={'|'.join(str(c) for c in store.bucket_capacities)}",
        ),
        csv_row(
            "index/cascade", t_cas * 1e6,
            f"k={k};candidates={s['candidates_scanned']};"
            f"stage0_pruned={s['stage0_pruned']};stage1_pruned={s['stage1_pruned']};"
            f"exact_refines={s['exact_refines']};"
            f"prune_fraction={s['prune_fraction']:.4f};identical={identical}",
        ),
        csv_row(
            "index/bruteforce", t_bru * 1e6,
            f"k={k};exact_refines={ref.stats['exact_refines']};"
            f"speedup_vs_cascade={t_bru/t_cas:.2f}x",
        ),
    ]
    REPORT.append(
        f"index ({n_sets} sets, D={d}, k={k}): cascade {t_bru/t_cas:.1f}x vs brute "
        f"force, {s['exact_refines']}/{n_sets} exact refines "
        f"(prune_fraction={s['prune_fraction']:.3f}), identical top-k: {identical}"
    )
    return rows


def bench_index_stage2(n_sets: int = 2000, d: int = 16, k: int = 10) -> list[str]:
    """PR 4 tentpole: batched vs sequential stage-2 frontier refinement.

    Same certified cascade, same corpus, same query — only the stage-2
    dispatch granularity differs:

    - ``batched``    — one vmapped masked exact pass per surviving bucket
      (±fp_margin tightening), then raw refines for the ≈ k boundary
      candidates only;
    - ``sequential`` — the legacy loop: one raw front-door refine per
      frontier candidate.

    The corpus sizes are RAGGED on purpose (quantized to multiples of 8 so
    brute force stays compilable): sequential stage 2 pays one jit trace
    per distinct raw set shape it refines, batched one per (bucket
    capacity, pow2 batch) pair.  Derived fields carry the
    ``scripts/check.sh`` gate: ``identical`` (vs brute force, bit for
    bit), ``refines``, ``stage2_calls``, ``stage2_shapes`` and the
    batched-vs-sequential speedup.
    """
    import numpy as np

    from repro.data.pointclouds import clustered_sets
    from repro.hd import search
    from repro.index import SetStore

    key = jax.random.fold_in(KEY, 2718)
    sets, _ = clustered_sets(key, n_sets, d, sizes=tuple(range(48, 257, 8)))

    store = SetStore(dim=d)
    store.add_many(sets)
    store.summaries()
    store.packed_buckets()

    qrng = np.random.RandomState(11)
    q = np.asarray(sets[0]).mean(axis=0) + qrng.randn(128, d).astype(np.float32) * 0.5

    t_bat, res_bat = timed(lambda: search(q, store, k, stage2="batched"), iters=3)
    t_seq, res_seq = timed(lambda: search(q, store, k, stage2="sequential"), iters=3)
    t_bru, ref = timed_once(lambda: search(q, store, k, method="exact"))

    def against_ref(res):
        return bool(
            np.array_equal(res.ids, ref.ids) and np.array_equal(res.values, ref.values)
        )

    def derived(res, t, identical):
        s = res.stats
        return (
            f"k={k};candidates={s['candidates_scanned']};"
            f"refines={s['exact_refines']};stage2_calls={s['stage2_calls']};"
            f"stage2_shapes={s['stage2_distinct_shapes']};"
            f"speedup_vs_sequential={t_seq/t:.2f}x;identical={identical}"
        )

    ib, isq = against_ref(res_bat), against_ref(res_seq)
    rows = [
        csv_row("index_stage2/batched", t_bat * 1e6, derived(res_bat, t_bat, ib)),
        csv_row("index_stage2/sequential", t_seq * 1e6, derived(res_seq, t_seq, isq)),
        csv_row(
            "index_stage2/bruteforce", t_bru * 1e6,
            f"k={k};refines={ref.stats['exact_refines']};"
            f"speedup_vs_batched={t_bru/t_bat:.2f}x",
        ),
    ]
    REPORT.append(
        f"index stage2 ({n_sets} ragged sets, D={d}, k={k}): batched "
        f"{t_seq/t_bat:.2f}x vs sequential "
        f"({res_bat.stats['exact_refines']} vs {res_seq.stats['exact_refines']} raw "
        f"refines, {res_bat.stats['stage2_distinct_shapes']} vs "
        f"{res_seq.stats['stage2_distinct_shapes']} stage-2 jit shapes), "
        f"identical top-k: {ib and isq}"
    )
    return rows


def bench_bucket_kernel(n_sets: int = 2000, d: int = 16, k: int = 10) -> list[str]:
    """PR 5 tentpole: the batched bucket kernel's stage-2a route vs the
    historical per-lane ``dense``/``tiled`` mirrors.

    Two measurements on the PR 4 ragged corpus (same sizes, same query, so
    the trajectory stays comparable):

    - ``stage2a_*`` — the isolated bucket pass: one full-slab
      ``masked_exact_hd_batched`` per storage bucket.  Per-bucket timings
      are interleaved across backends and min-reduced over N reps (drift
      hits every backend alike; the minimum estimates the true floor),
      then summed.  This is the gated number: the batched route must be
      ≤ 1.0× the best existing backend's wall clock on CPU, within the
      session's own measured timing noise — interpret-mode Pallas is
      EXCLUDED (a testing path; the CPU batched route is the pure-JAX
      batched mirror, one fused bidirectional pass per slab instead of
      dense's two directed GEMM passes).
    - ``stage2a_selfnoise`` — the SAME backend (dense) timed as two
      independent interleaved contenders; the deviation of their ratio
      from 1.0 is the session's timing-noise floor.  All exact
      formulations land within a few percent of each other at these
      shapes, so an unqualified 1.0× assertion would gate on scheduler
      luck; the self-noise row makes the measurement error explicit and
      machine-checkable instead.
    - ``search_*`` — the end-to-end cascade under each ``masked_backend``,
      with the identical-top-k assertion vs brute force and the per-search
      launch accounting (``stage2_calls`` = one jitted dispatch per
      surviving bucket + one raw refine per boundary candidate).
    """
    import functools
    import time as _time

    import jax.numpy as jnp
    import numpy as np

    from repro.core import masked as _masked
    from repro.data.pointclouds import clustered_sets
    from repro.hd import resolver, search
    from repro.index import SetStore

    key = jax.random.fold_in(KEY, 2718)
    sets, _ = clustered_sets(key, n_sets, d, sizes=tuple(range(48, 257, 8)))
    store = SetStore(dim=d)
    store.add_many(sets)
    buckets = store.packed_buckets()

    qrng = np.random.RandomState(11)
    q = jnp.asarray(
        np.asarray(sets[0]).mean(axis=0) + qrng.randn(128, d).astype(np.float32) * 0.5
    )

    device_kind = resolver.default_device_kind()
    batched_be = resolver.resolve_masked_backend(128, 0, d, device_kind=device_kind)
    # timer id -> backend; "selfnoise" re-times dense as an independent
    # contender to expose the session's measurement-error floor.
    timers = {batched_be: batched_be, "dense": "dense", "tiled": "tiled",
              "selfnoise": "dense"}

    @functools.partial(jax.jit, static_argnames=("backend", "block_a", "block_b"))
    def slab_pass(qq, pts, valid, *, backend, block_a, block_b):
        return _masked.masked_exact_hd_batched(
            qq, pts, valid_slab=valid, backend=backend,
            block_a=block_a, block_b=block_b,
        )

    def one_bucket(be, cap):
        b = buckets[cap]
        block_a, block_b = resolver.resolve_block_sizes(
            128, cap, d, device_kind=device_kind,
            backend="fused_pallas" if be == "batched_pallas" else "tiled",
        )
        slab_pass(
            q, b.points, b.valid, backend=be, block_a=block_a, block_b=block_b
        ).block_until_ready()

    for be in set(timers.values()):
        for cap in buckets:
            one_bucket(be, cap)  # compile
    best = {t: {cap: float("inf") for cap in buckets} for t in timers}
    for _ in range(12):
        for cap in sorted(buckets):
            for tname, be in timers.items():
                t0 = _time.perf_counter()
                one_bucket(be, cap)
                best[tname][cap] = min(best[tname][cap], _time.perf_counter() - t0)
    floor = {t: sum(per.values()) for t, per in best.items()}

    best_existing = min(floor["dense"], floor["tiled"])
    ratio = floor[batched_be] / best_existing
    noise = abs(floor["selfnoise"] / floor["dense"] - 1.0)

    t_bru, ref = timed_once(lambda: search(q, store, k, method="exact"))
    rows = []
    for be in (batched_be, "dense", "tiled"):
        t, res = timed(lambda be=be: search(q, store, k, masked_backend=be), iters=3)
        identical = bool(
            np.array_equal(res.ids, ref.ids) and np.array_equal(res.values, ref.values)
        )
        s = res.stats
        rows.append(
            csv_row(
                f"bucket_kernel/search_{be}", t * 1e6,
                f"k={k};identical={identical};refines={s['exact_refines']};"
                f"stage2_calls={s['stage2_calls']};"
                f"stage2_batched={s['stage2_batched_candidates']};"
                f"speedup_vs_brute={t_bru/t:.2f}x",
            )
        )
    for tname in (batched_be, "dense", "tiled"):
        name = "batched" if tname == batched_be else tname
        rows.append(
            csv_row(
                f"bucket_kernel/stage2a_{name}", floor[tname] * 1e6,
                f"backend={timers[tname]};caps={len(buckets)};"
                f"ratio_vs_best_existing={floor[tname]/best_existing:.4f}",
            )
        )
    rows.append(
        csv_row(
            "bucket_kernel/stage2a_selfnoise", floor["selfnoise"] * 1e6,
            f"backend=dense;noise_floor={noise:.4f}",
        )
    )
    REPORT.append(
        f"bucket kernel ({n_sets} ragged sets, D={d}): stage-2a {batched_be} "
        f"{floor[batched_be]*1e3:.0f}ms vs best existing {best_existing*1e3:.0f}ms "
        f"({ratio:.3f}x; gate <= 1.0x within self-measured noise {noise:.3f}), "
        f"top-k identical under all backends"
    )
    return rows


def bench_dispatch_overhead() -> list[str]:
    """PR 2: the front door's python dispatch cost vs the direct kernel call.

    Both sides run the IDENTICAL jitted fused-Pallas computation; the
    delta is registry lookup + context assembly + HDResult packing.
    scripts/check.sh runs this with ``--only dispatch --json BENCH_PR2.json``
    and gates on overhead < 5%.  Best-of-N timing (not median) so machine
    noise cannot manufacture overhead that is not there.
    """
    import time as _time

    from repro.hd import HDConfig, set_distance
    from repro.kernels.hausdorff import ops as hd_ops

    n, d, blk = 2048, 32, 512
    a, b = dataset("random", n, n, d)
    cfg = HDConfig(block_a=blk, block_b=blk)

    def direct():
        return hd_ops.hausdorff(a, b, block_a=blk, block_b=blk)

    def front_door():
        return set_distance(
            a, b, variant="hausdorff", method="exact", backend="fused_pallas",
            config=cfg,
        ).value

    def one(fn) -> float:
        t0 = _time.perf_counter()
        jax.block_until_ready(fn())
        return _time.perf_counter() - t0

    # Interleave the two sides (direct, front, direct, front, …) so slow
    # machine-level drift (GC, page cache, turbo) hits both equally, and
    # take each side's best.
    jax.block_until_ready(direct())  # compile + warm caches
    jax.block_until_ready(front_door())
    # Interpret-mode Pallas allocates heavily → GC pauses land on random
    # iterations and dwarf the ~µs dispatch delta being measured; park the
    # collector for the timed region.
    import gc as _gc

    _gc.collect()
    _gc.disable()
    try:
        t_direct = t_front = float("inf")
        for _ in range(21):
            t_direct = min(t_direct, one(direct))
            t_front = min(t_front, one(front_door))
    finally:
        _gc.enable()
    h_direct = float(direct())
    h_front = float(front_door())
    overhead = (t_front - t_direct) / t_direct * 100.0
    REPORT.append(
        f"dispatch ({n}x{n},D={d}): front-door overhead {overhead:+.2f}% "
        f"vs direct fused call (values equal: {h_direct == h_front})"
    )
    return [
        csv_row("dispatch/direct", t_direct * 1e6, f"hd={h_direct:.5f};block={blk}"),
        csv_row(
            "dispatch/front_door", t_front * 1e6,
            f"hd={h_front:.5f};overhead_pct={overhead:.2f};block={blk}",
        ),
    ]


def bench_reliability(n_sets: int = 5000, d: int = 16, k: int = 10) -> list[str]:
    """PR 6 tentpole: the reliability layer's cost, measured end to end.

    Four rows on the same clustered 5k-set corpus bench_index uses:

    - ``reliability/snapshot`` / ``reliability/restore`` — durable SetStore
      save/restore wall time; the restored store must reproduce the live
      store's certified top-k BIT-FOR-BIT (``identical`` gated by
      scripts/check.sh);
    - ``reliability/degraded`` — deadline-floor search latency (stage-0
      certified intervals only, ``deadline_s=0``) vs the full cascade:
      what a caller pays for an instant degraded answer;
    - ``reliability/recovery`` — service flush latency when the FIRST
      attempt of the search dies with an injected transient fault and the
      retry machinery (run_with_recovery, zero backoff here) recovers —
      vs an uninjected flush of the same request.

    Plus ``reliability/corrupt_detect``: wall time for sha256 verification
    to catch one flipped byte in a snapshot (``detected`` gated).
    """
    import shutil
    import tempfile
    import time as _time

    import numpy as np

    from repro.data.pointclouds import clustered_sets
    from repro.hd import search
    from repro.index import SetStore
    from repro.reliability import (
        Fault,
        StoreCorruption,
        corrupt_snapshot,
        inject,
    )
    from repro.serve.server import ProHDService, ServeConfig

    key = jax.random.fold_in(KEY, 2718)
    sets, _labels = clustered_sets(key, n_sets, d, sizes=(64, 128, 256))
    store = SetStore(dim=d)
    store.add_many(sets)
    store.summaries()
    store.packed_buckets()

    qrng = np.random.RandomState(11)
    q = np.asarray(sets[0]).mean(axis=0) + qrng.randn(128, d).astype(np.float32) * 0.5
    base = search(q, store, k)

    root = tempfile.mkdtemp(prefix="bench_reliability_")
    try:
        t0 = _time.perf_counter()
        snap = store.save(root)
        t_save = _time.perf_counter() - t0

        t0 = _time.perf_counter()
        restored = SetStore.restore(root)
        t_restore = _time.perf_counter() - t0
        res_r = search(q, restored, k)
        identical = bool(
            np.array_equal(res_r.ids, base.ids)
            and np.array_equal(res_r.values, base.values)
        )

        t0 = _time.perf_counter()
        corrupt_snapshot(snap, seed=5)
        try:
            SetStore.restore(root)
            detected = False
        except StoreCorruption:
            detected = True
        t_detect = _time.perf_counter() - t0
    finally:
        shutil.rmtree(root, ignore_errors=True)

    t_full, _ = timed(lambda: search(q, store, k), iters=3)
    t_deg, res_deg = timed(lambda: search(q, store, k, deadline_s=0.0), iters=3)
    sound = bool(
        res_deg.degraded and np.all(res_deg.lower <= res_deg.upper)
    )

    svc = ProHDService(ServeConfig(min_store_bucket=8, retry_backoff_s=0.0), store=store)
    svc.submit_search(q, k)
    t0 = _time.perf_counter()
    svc.flush()
    t_clean = _time.perf_counter() - t0
    svc.submit_search(q, k)
    with inject(Fault("serve.flush", action="raise", once=True)):
        t0 = _time.perf_counter()
        out = svc.flush()
        t_recover = _time.perf_counter() - t0
    recovered = bool(all("error" not in v for v in out.values()))

    gb = store.total_points * d * 4 / 1e9
    rows = [
        csv_row(
            "reliability/snapshot", t_save * 1e6,
            f"n_sets={n_sets};points={store.total_points};mb={gb*1e3:.1f}",
        ),
        csv_row(
            "reliability/restore", t_restore * 1e6,
            f"n_sets={n_sets};identical={identical}",
        ),
        csv_row(
            "reliability/corrupt_detect", t_detect * 1e6,
            f"detected={detected}",
        ),
        csv_row(
            "reliability/degraded", t_deg * 1e6,
            f"k={k};vs_full={t_full/t_deg:.1f}x;stage={res_deg.stage_reached};"
            f"sound={sound}",
        ),
        csv_row(
            "reliability/recovery", t_recover * 1e6,
            f"clean_us={t_clean*1e6:.0f};overhead={t_recover/t_clean:.2f}x;"
            f"recovered={recovered}",
        ),
    ]
    REPORT.append(
        f"reliability ({n_sets} sets): snapshot {t_save*1e3:.0f}ms / restore "
        f"{t_restore*1e3:.0f}ms (identical top-k: {identical}), corrupt byte "
        f"detected: {detected}, degraded floor {t_full/t_deg:.0f}x faster than "
        f"full cascade (sound: {sound}), injected-fault recovery "
        f"{t_recover/t_clean:.1f}x a clean flush (recovered: {recovered})"
    )
    return rows


def bench_multiquery(n_sets: int = 5000, d: int = 16, k: int = 10) -> list[str]:
    """PR 7 tentpole: batched multi-query cascade vs a sequential loop.

    Q=64 queries against the same clustered 5k-set corpus bench_index
    uses, drawn WITH duplicates from 24 unique query blobs (a realistic
    serving mix: hot queries repeat).  Three interleaved, min-reduced
    timers:

    - ``multiquery/sequential`` — Q independent ``search()`` calls, the
      baseline every batching claim must beat;
    - ``multiquery/batched`` — ONE ``search_batch`` call: shared stage-0
      (Q x corpus) bound pass, one query-axis bucket launch per surviving
      capacity, duplicate queries collapsed, at most one raw refine per
      (unique query, candidate).  Gated by scripts/check.sh: >= 2.0x the
      sequential throughput at Q=64, within self-measured noise, with
      per-query top-k IDENTICAL to the sequential results bit-for-bit;
    - ``multiquery/selfnoise`` — the batched call timed again as an
      independent contender; the deviation of the two floors' ratio from
      1.0 is the session's timing-noise floor, making the 2.0x gate
      machine-checkable instead of scheduler luck.
    """
    import time as _time

    import numpy as np

    from repro.data.pointclouds import clustered_sets
    from repro.hd import search, search_batch
    from repro.index import SetStore

    key = jax.random.fold_in(KEY, 2718)
    sets, _labels = clustered_sets(key, n_sets, d, sizes=(64, 128, 256))
    store = SetStore(dim=d)
    store.add_many(sets)
    store.summaries()
    store.packed_buckets()

    qrng = np.random.RandomState(11)
    uniq = [
        np.asarray(sets[i * 97 % n_sets]).mean(axis=0)
        + qrng.randn(128, d).astype(np.float32) * 0.5
        for i in range(24)
    ]
    queries = [uniq[j] for j in qrng.randint(0, len(uniq), size=64)]

    def run_seq():
        return [search(q, store, k) for q in queries]

    def run_bat():
        return search_batch(queries, store, k)

    ref = run_seq()  # compile + correctness reference
    bat = run_bat()
    identical = all(
        bool(np.array_equal(b.ids, s.ids) and np.array_equal(b.values, s.values))
        for b, s in zip(bat, ref)
    )

    timers = {"sequential": run_seq, "batched": run_bat, "selfnoise": run_bat}
    floor = {t: float("inf") for t in timers}
    for _ in range(3):
        for tname, fn in timers.items():
            t0 = _time.perf_counter()
            fn()
            floor[tname] = min(floor[tname], _time.perf_counter() - t0)

    ratio = floor["sequential"] / floor["batched"]
    noise = abs(floor["selfnoise"] / floor["batched"] - 1.0)
    stats = bat[0].stats
    n_queries = len(queries)
    refines_per_query = (
        sum(r.stats["exact_refines"] for r in bat) / n_queries
    )
    rows = [
        csv_row(
            "multiquery/sequential", floor["sequential"] * 1e6,
            f"Q={n_queries};qps={n_queries/floor['sequential']:.2f};k={k}",
        ),
        csv_row(
            "multiquery/batched", floor["batched"] * 1e6,
            f"Q={n_queries};qps={n_queries/floor['batched']:.2f};k={k};"
            f"speedup_vs_sequential={ratio:.3f};identical={identical};"
            f"refines_per_query={refines_per_query:.2f};"
            f"dedup_hit_rate={stats['dedup_hit_rate']:.4f};"
            f"unique_queries={stats['unique_queries']};"
            f"launches={stats['multiquery_launches']};"
            f"masked_backend={stats['masked_backend']}",
        ),
        csv_row(
            "multiquery/selfnoise", floor["selfnoise"] * 1e6,
            f"noise_floor={noise:.4f}",
        ),
    ]
    REPORT.append(
        f"multiquery ({n_sets} clustered sets, D={d}, Q={n_queries}, k={k}): "
        f"batched {n_queries/floor['batched']:.1f} q/s vs sequential "
        f"{n_queries/floor['sequential']:.1f} q/s ({ratio:.2f}x; gate >= 2.0x "
        f"within self-measured noise {noise:.3f}), "
        f"{refines_per_query:.1f} refines/query, dedup hit rate "
        f"{stats['dedup_hit_rate']:.2f}, identical top-k: {identical}"
    )
    return rows


def bench_obs(n_sets: int = 5000, d: int = 16, k: int = 10) -> list[str]:
    """PR 8 tentpole: the repro.obs tracing layer's overhead contract.

    The same 5k-set clustered corpus and query as ``bench_index``, timed
    three ways with interleaved min-reduced timers:

    - ``obs/cascade_disabled`` — ``search()`` with tracing OFF, i.e. the
      instrumented hot path paying only the no-op fast path (one module
      flag check + a shared inert span object per site);
    - ``obs/selfnoise`` — the disabled call timed again as an independent
      contender; the deviation of the two floors' ratio from 1.0 is the
      session's timing-noise floor;
    - ``obs/cascade_enabled`` — the same call with tracing ON (in-memory
      collector, no JSONL), the full cost of real spans + the metrics
      fold.  ``scripts/check.sh`` gates enabled overhead < 15% vs
      disabled, within the self-measured noise.

    ``obs/noop_site`` microbenchmarks one disabled instrumentation site
    (``with span(name, attr=..)``) directly; its derived field carries the
    estimated whole-search no-op overhead (sites x ns / search time),
    which check.sh gates < 5% — the "disabled by default costs nothing"
    half of the contract.  A schema-validated capture of one enabled
    search feeds the per-stage latency table appended to the findings.
    """
    import time as _time

    import numpy as np

    from repro.data.pointclouds import clustered_sets
    from repro.hd import search
    from repro.index import SetStore
    from repro.obs import export as _export
    from repro.obs import report as _report
    from repro.obs import trace as _trace

    key = jax.random.fold_in(KEY, 3141)
    sets, _labels = clustered_sets(key, n_sets, d, sizes=(64, 128, 256))
    store = SetStore(dim=d)
    store.add_many(sets)
    store.summaries()
    store.packed_buckets()
    qrng = np.random.RandomState(7)
    q = np.asarray(sets[0]).mean(axis=0) + qrng.randn(128, d).astype(np.float32) * 0.5

    def run():
        return search(q, store, k)

    run()  # compile outside every measured region

    # one enabled, schema-validated capture for the span census + table
    with _trace.capture() as get_events:
        run()
        captured = get_events()
    try:
        summary = _export.validate_events(captured)
        schema_valid = True
    except _export.SchemaError:
        summary = {"rids": []}
        schema_valid = False
    n_spans = sum(1 for e in captured if e["type"] == "span")
    n_events = len(captured) - n_spans

    timers = ("disabled", "selfnoise", "enabled")
    floor = {t: float("inf") for t in timers}
    for _ in range(5):
        for tname in timers:
            if tname == "enabled":
                _trace.enable()
            t0 = _time.perf_counter()
            run()
            dt = _time.perf_counter() - t0
            if tname == "enabled":
                _trace.disable()
                _trace.drain()
            floor[tname] = min(floor[tname], dt)

    noise = abs(floor["selfnoise"] / floor["disabled"] - 1.0)
    enabled_pct = (floor["enabled"] / floor["disabled"] - 1.0) * 100.0

    # no-op site microbench: the per-site cost tracing-off, net of loop
    # overhead.  SITES is a deliberate overcount of the spans+events one
    # search() traverses (root + 4 stages + resolution/stats sites).
    iters = 200_000
    t0 = _time.perf_counter()
    for _ in range(iters):
        pass
    t_empty = _time.perf_counter() - t0
    t0 = _time.perf_counter()
    for _ in range(iters):
        with _trace.span("obs.noop_site", n=iters):
            pass
    t_site = _time.perf_counter() - t0
    site_ns = max(t_site - t_empty, 0.0) / iters * 1e9
    SITES = 16
    noop_pct = SITES * site_ns * 1e-9 / floor["disabled"] * 100.0

    rows = [
        csv_row(
            "obs/cascade_disabled", floor["disabled"] * 1e6,
            f"n_sets={n_sets};k={k};tracing=off",
        ),
        csv_row(
            "obs/selfnoise", floor["selfnoise"] * 1e6,
            f"noise_floor={noise:.4f}",
        ),
        csv_row(
            "obs/cascade_enabled", floor["enabled"] * 1e6,
            f"overhead_vs_disabled_pct={enabled_pct:.2f};spans={n_spans};"
            f"events={n_events};rids={len(summary['rids'])};"
            f"schema_valid={schema_valid}",
        ),
        csv_row(
            "obs/noop_site", site_ns / 1e3,
            f"site_ns={site_ns:.1f};sites_per_search={SITES};"
            f"est_noop_overhead_pct={noop_pct:.4f}",
        ),
    ]
    REPORT.append(
        f"obs ({n_sets} sets, k={k}): disabled {floor['disabled']*1e3:.2f}ms, "
        f"enabled {floor['enabled']*1e3:.2f}ms ({enabled_pct:+.1f}%, gate < 15% "
        f"within noise {noise:.3f}); no-op site {site_ns:.0f}ns -> estimated "
        f"disabled overhead {noop_pct:.3f}% (gate < 5%); one search = "
        f"{n_spans} spans + {n_events} events, single rid: "
        f"{len(summary['rids']) == 1}, schema valid: {schema_valid}"
    )
    for line in _report.stage_table(captured).splitlines():
        REPORT.append(line)
    return rows


def bench_anytime(n_sets: int = 5000, d: int = 16, k: int = 10) -> list[str]:
    """PR 9 tentpole: anytime certified search vs the exact cascade.

    A 5k-set corpus of 500 well-separated clusters of EXACTLY k sets each
    (the vector-DB regime where top-k = one semantic cluster): stage-0
    summary bounds alone certify cluster membership, so an anytime search
    with a cluster-scale ε converges before any kernel work while the
    exact cascade still pays stage 1 + stage 2a + k raw refines for the
    bit-for-bit ordering nobody asked for.  ε is 5% of the CORPUS distance
    scale — the median stage-0 certified upper bound from query to corpus
    (reported as ``scale`` so the gate is self-describing).

    Three interleaved, min-reduced timers:

    - ``anytime/exact`` — the exact cascade, the baseline;
    - ``anytime/anytime`` — the same query at ``mode="anytime"``,
      ε = 5% of scale.  Gated by scripts/check.sh: >= 2.0x the exact
      floor within self-measured noise, AT certified recall >= 0.95
      (the certificate the result itself reports — the speed is
      meaningless if the ladder stopped before it could prove the hits);
    - ``anytime/selfnoise`` — the anytime call timed again as an
      independent contender; the deviation of the two floors' ratio from
      1.0 is the session's timing-noise floor.
    """
    import time as _time

    import numpy as np

    from repro.hd import search
    from repro.index import SetStore

    rng = np.random.RandomState(2026)
    n_clusters, per = n_sets // k, k
    centers = rng.randn(n_clusters, d).astype(np.float32) * 50.0
    sets = []
    for c in range(n_clusters):
        for _ in range(per):
            n = int(rng.choice((64, 128, 256)))
            sets.append(centers[c] + rng.randn(n, d).astype(np.float32) * 0.25)
    store = SetStore(dim=d)
    store.add_many(sets)
    store.summaries()
    store.packed_buckets()
    q = centers[0] + rng.randn(128, d).astype(np.float32) * 0.25

    # corpus distance scale: the median stage-0 certified upper bound over
    # the whole corpus (a full-depth anytime probe at vacuous ε returns
    # exactly the stage-0 intervals, no kernel work)
    probe = search(q, store, store.n_sets, mode="anytime", epsilon=1e12)
    dist_scale = float(np.median(np.asarray(probe.upper)))
    eps = 0.05 * dist_scale

    def run_exact():
        return search(q, store, k)

    def run_any():
        return search(q, store, k, mode="anytime", epsilon=eps)

    ref = run_exact()  # compile + correctness reference
    res = run_any()
    same_ids = sorted(res.ids.tolist()) == sorted(ref.ids.tolist())

    timers = {"exact": run_exact, "anytime": run_any, "selfnoise": run_any}
    floor = {t: float("inf") for t in timers}
    for _ in range(5):
        for tname, fn in timers.items():
            t0 = _time.perf_counter()
            fn()
            floor[tname] = min(floor[tname], _time.perf_counter() - t0)

    speedup = floor["exact"] / floor["anytime"]
    noise = abs(floor["selfnoise"] / floor["anytime"] - 1.0)
    recall = float(res.certified_recall_at_k)
    rows = [
        csv_row(
            "anytime/exact", floor["exact"] * 1e6,
            f"n_sets={n_sets};k={k};refines={ref.stats['exact_refines']};"
            f"stage={ref.stage_reached}",
        ),
        csv_row(
            "anytime/anytime", floor["anytime"] * 1e6,
            f"epsilon={eps:.4f};scale={dist_scale:.2f};"
            f"speedup_vs_exact={speedup:.3f};certified_recall={recall:.4f};"
            f"converged={res.stats['converged']};stage={res.stage_reached};"
            f"anytime_refines={res.stats['anytime_refines']};"
            f"same_id_set={same_ids}",
        ),
        csv_row(
            "anytime/selfnoise", floor["selfnoise"] * 1e6,
            f"noise_floor={noise:.4f}",
        ),
    ]
    REPORT.append(
        f"anytime ({n_sets} sets in {n_clusters} clusters of {per}, k={k}): "
        f"anytime {floor['anytime']*1e3:.1f}ms vs exact "
        f"{floor['exact']*1e3:.1f}ms ({speedup:.2f}x; gate >= 2.0x within "
        f"self-measured noise {noise:.3f}) at ε={eps:.2f} (5% of corpus "
        f"distance scale {dist_scale:.1f}), certified recall {recall:.2f} "
        f"(gate >= 0.95), converged={res.stats['converged']} at "
        f"{res.stage_reached} with {res.stats['anytime_refines']} refines, "
        f"identical id set: {same_ids}"
    )
    return rows


def bench_sharded(n_sets: int = 5000, d: int = 16, k: int = 10) -> list[str]:
    """PR 10 tentpole: shard_map corpus-parallel cascade + mutable store.

    The same 5k-set clustered corpus as ``bench_index``, searched three
    ways — in-process single-device, ``shards=1`` (the full shard_map
    route on a one-device mesh, isolating the sharding machinery's
    overhead), and ``shards=<all devices>``.  Per-shard stage-0/stage-1
    timings come from the obs trace of one sharded search: the
    ``cascade.stage0`` / ``cascade.stage1`` / ``cascade.shard_merge``
    span durations, each row carrying its ``shards`` attr.  Every
    sharded result is asserted bit-for-bit equal to the in-process one
    (``identical=...`` in the derived fields) — the identity
    ``scripts/check.sh`` gates on.

    Mutation rows: delete 30% of the corpus, compact, and search again
    (single-device and max-shards) — ``survivor_identical`` asserts the
    post-compaction top-k still matches brute force over the survivors.

    Run under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` for
    a multi-device sweep on CPU; on one device the max-shards rows
    coincide with ``shards=1``.
    """
    import time as _time

    import numpy as np

    from repro.data.pointclouds import clustered_sets
    from repro.hd import search
    from repro.index import SetStore
    from repro.obs import trace

    key = jax.random.fold_in(KEY, 10_10)
    sets, _labels = clustered_sets(key, n_sets, d, sizes=(64, 128, 256))
    store = SetStore(dim=d)
    store.add_many(sets)
    store.summaries()
    store.packed_buckets()

    qrng = np.random.RandomState(11)
    q = np.asarray(sets[0]).mean(axis=0) + qrng.randn(128, d).astype(np.float32) * 0.5

    p_max = jax.device_count()
    ref = search(q, store, k)  # compile + in-process reference

    def _identical(res):
        return bool(
            np.array_equal(res.ids, ref.ids)
            and np.array_equal(res.values, ref.values)
        )

    rows: list[str] = []
    t_base, _ = timed(lambda: search(q, store, k), iters=3)
    rows.append(
        csv_row(
            "sharded/baseline", t_base * 1e6,
            f"n_sets={n_sets};d={d};k={k};devices={p_max}",
        )
    )
    per_shard: dict[int, dict[str, float]] = {}
    idents: dict[int, bool] = {}
    for p in sorted({1, p_max}):
        res_p = search(q, store, k, shards=p)  # compile the p-shard route
        idents[p] = _identical(res_p)
        t_p, _ = timed(lambda p=p: search(q, store, k, shards=p), iters=3)
        rows.append(
            csv_row(
                f"sharded/shards{p}", t_p * 1e6,
                f"shards={p};identical={idents[p]};"
                f"vs_baseline={t_base / t_p:.3f}x",
            )
        )
        # per-shard stage timings: one traced search, span durations
        with trace.capture() as get_events:
            search(q, store, k, shards=p)
            events = get_events()
        stages = {
            e["name"]: e for e in events
            if e["type"] == "span"
            and e["name"] in ("cascade.stage0", "cascade.stage1", "cascade.shard_merge")
        }
        per_shard[p] = {n: float(e["dur_s"]) for n, e in stages.items()}
        for name, e in sorted(stages.items()):
            rows.append(
                csv_row(
                    f"sharded/{name.split('.', 1)[1]}/shards{p}",
                    float(e["dur_s"]) * 1e6,
                    f"shards={e['attrs'].get('shards', p)};"
                    f"per_shard_us={float(e['dur_s']) * 1e6 / p:.1f}",
                )
            )

    # ---- mutation: delete 30%, compact, search the survivors ----------
    victims = list(range(0, n_sets, 10)) + list(range(1, n_sets, 5))
    for sid in victims:
        store.delete(sid)
    t0 = _time.perf_counter()
    removed = store.compact()
    t_compact = _time.perf_counter() - t0
    mut_ref = search(q, store, k, method="exact")  # brute force, survivors
    mut_res = search(q, store, k)
    t_mut, _ = timed(lambda: search(q, store, k), iters=3)
    surv_ok = bool(
        np.array_equal(mut_res.ids, mut_ref.ids)
        and np.array_equal(mut_res.values, mut_ref.values)
    )
    mut_shard = search(q, store, k, shards=p_max)
    shard_ok = bool(
        np.array_equal(mut_shard.ids, mut_ref.ids)
        and np.array_equal(mut_shard.values, mut_ref.values)
    )
    rows += [
        csv_row(
            "sharded/compact", t_compact * 1e6,
            f"deleted={n_sets - store.n_live};n_live={store.n_live};"
            f"slots_removed={sum(removed.values())};"
            f"buckets_rewritten={len(removed)}",
        ),
        csv_row(
            "sharded/mutated", t_mut * 1e6,
            f"n_live={store.n_live};survivor_identical={surv_ok};"
            f"sharded_survivor_identical={shard_ok};shards={p_max}",
        ),
    ]
    s0 = per_shard[p_max]
    REPORT.append(
        f"sharded ({n_sets} sets, d={d}, k={k}, {p_max} device(s)): baseline "
        f"{t_base*1e3:.1f}ms, shards={p_max} stage0 "
        f"{s0.get('cascade.stage0', 0)*1e3:.2f}ms / stage1 "
        f"{s0.get('cascade.stage1', 0)*1e3:.2f}ms / merge "
        f"{s0.get('cascade.shard_merge', 0)*1e3:.2f}ms; sharded top-k "
        f"bit-for-bit: {all(idents.values())}; "
        f"after delete-30%+compact ({store.n_live} live) survivor top-k == "
        f"brute force: {surv_ok}, sharded: {shard_ok}"
    )
    return rows
