#!/usr/bin/env bash
# Standard builder loop: tier-1 tests + quick benchmark with machine-readable
# output.  Run from the repo root:
#
#   ./scripts/check.sh            # tests + quick bench -> BENCH_PR1.json
#   SKIP_BENCH=1 ./scripts/check.sh   # tests only
#
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 pytest =="
python -m pytest -x -q

if [[ -z "${SKIP_BENCH:-}" ]]; then
  # Separate path: BENCH_PR1.json is the committed cross-PR trajectory
  # (written by `--only backends --json`); the quick loop must not clobber
  # it with an incomparable row set.
  echo "== quick benchmark (JSON -> BENCH_QUICK.json) =="
  python -m benchmarks.run --quick --json BENCH_QUICK.json

  # PR 2 gate: the repro.hd front door must stay a thin veneer — its
  # dispatch overhead on the fused path is asserted < 5% of the kernel
  # call it wraps (best-of-N timing on both sides).
  echo "== dispatch-overhead microbench (JSON -> BENCH_PR2.json) =="
  python -m benchmarks.run --only dispatch --json BENCH_PR2.json
  python - <<'PY'
import json

rows = {r["name"]: r for r in json.load(open("BENCH_PR2.json"))["rows"]}
direct = rows["dispatch/direct"]["us_per_call"]
front = rows["dispatch/front_door"]["us_per_call"]
overhead = (front - direct) / direct * 100.0
print(f"front-door dispatch overhead: {overhead:+.2f}% "
      f"(direct {direct:.0f}us, front door {front:.0f}us)")
assert overhead < 5.0, f"front-door overhead {overhead:.2f}% exceeds the 5% budget"
PY

  # PR 3 gate: on a separated-clusters corpus the certified bound cascade
  # must (a) return top-k ids AND values bit-for-bit identical to brute
  # force, (b) perform < 50% of brute force's exact refines, and
  # (c) record prune_fraction > 0.5 in BENCH_PR3.json.
  echo "== index-cascade benchmark (JSON -> BENCH_PR3.json) =="
  python -m benchmarks.run --only index --json BENCH_PR3.json
  python - <<'PY'
import json

rows = {r["name"]: r for r in json.load(open("BENCH_PR3.json"))["rows"]}
derived = dict(kv.split("=", 1) for kv in rows["index/cascade"]["derived"].split(";"))
refines = int(derived["exact_refines"])
candidates = int(derived["candidates"])
prune = float(derived["prune_fraction"])
identical = derived["identical"] == "True"
print(f"index cascade: {refines}/{candidates} exact refines "
      f"(prune_fraction={prune:.3f}), identical top-k: {identical}")
assert identical, "cascade top-k differs from brute force"
assert refines < 0.5 * candidates, (
    f"cascade did {refines} exact refines, >= 50% of the {candidates}-set corpus")
assert prune > 0.5, f"prune_fraction {prune:.3f} <= 0.5 on a separated corpus"
PY
fi

# PR 4 gates.
# (a) The conformance harness: padded-masked vs raw reductions bitwise per
#     backend on this platform, layout invariances, and the pinned
#     fp-margin contract everywhere bitwise is unattainable.  The backend
#     list is NOT hard-coded: the sweep enumerates
#     repro.core.masked.EXACT_MASKED_BACKENDS at run time and runs each
#     backend's slice of the suite — a backend that registers but collects
#     zero conformance cases fails the gate (pytest exit 5: no tests
#     collected), so a new kernel cannot dodge certification.  The full
#     suite (incl. backend-agnostic modules) also runs under tier-1 above.
echo "== conformance suite (dynamic backend sweep) =="
MASKED_BACKENDS=$(python -c "from repro.core import masked; print(' '.join(sorted(masked.EXACT_MASKED_BACKENDS)))")
echo "registered masked exact backends: ${MASKED_BACKENDS}"
for be in ${MASKED_BACKENDS}; do
  echo "-- conformance[${be}] --"
  python -m pytest -q -m conformance tests/conformance -k "${be}"
done

# (b) Batched vs sequential stage-2 frontier refinement: identical top-k
#     (both bit-for-bit vs brute force), no more raw refines, fewer
#     distinct stage-2 jit shapes, and wall clock no slower (10% timing
#     grace) -> BENCH_PR4.json.
if [[ -z "${SKIP_BENCH:-}" ]]; then
  echo "== batched stage-2 benchmark (JSON -> BENCH_PR4.json) =="
  python -m benchmarks.run --only index_stage2 --json BENCH_PR4.json
  python - <<'PY'
import json

rows = {r["name"]: r for r in json.load(open("BENCH_PR4.json"))["rows"]}
bat = rows["index_stage2/batched"]
seq = rows["index_stage2/sequential"]
db = dict(kv.split("=", 1) for kv in bat["derived"].split(";"))
ds = dict(kv.split("=", 1) for kv in seq["derived"].split(";"))
print(f"stage2 batched:    {bat['us_per_call']:.0f}us, refines={db['refines']}, "
      f"jit shapes={db['stage2_shapes']}, identical={db['identical']}")
print(f"stage2 sequential: {seq['us_per_call']:.0f}us, refines={ds['refines']}, "
      f"jit shapes={ds['stage2_shapes']}, identical={ds['identical']}")
assert db["identical"] == "True", "batched stage-2 top-k differs from brute force"
assert ds["identical"] == "True", "sequential stage-2 top-k differs from brute force"
assert int(db["refines"]) <= int(ds["refines"]), (
    "batched stage 2 raw-refined MORE candidates than sequential")
assert int(db["stage2_shapes"]) < int(ds["stage2_shapes"]), (
    "batched stage 2 did not reduce distinct stage-2 jit shapes")
assert bat["us_per_call"] <= seq["us_per_call"] * 1.10, (
    f"batched stage 2 slower than sequential: "
    f"{bat['us_per_call']:.0f}us vs {seq['us_per_call']:.0f}us")
PY

  # PR 5 gate: the batched bucket kernel's stage-2a route (the pure-JAX
  # batched mirror on CPU — interpret-mode Pallas is excluded as a testing
  # path, and never resolved) must be <= 1.0x the best existing backend's
  # wall clock, within the session's own self-measured timing-noise floor
  # (the same backend timed as two independent interleaved contenders; see
  # the bench docstring), and every backend's search must return the
  # brute-force top-k bit for bit.
  echo "== bucket-kernel benchmark (JSON -> BENCH_PR5.json) =="
  python -m benchmarks.run --only bucket_kernel --json BENCH_PR5.json
  python - <<'PY'
import json

rows = {r["name"]: r for r in json.load(open("BENCH_PR5.json"))["rows"]}
bat = rows["bucket_kernel/stage2a_batched"]
db = dict(kv.split("=", 1) for kv in bat["derived"].split(";"))
noise = float(
    dict(kv.split("=", 1) for kv in
         rows["bucket_kernel/stage2a_selfnoise"]["derived"].split(";"))["noise_floor"]
)
ratio = float(db["ratio_vs_best_existing"])
# The dense-retimed noise floor underestimates cross-RUN drift: the 3-cap
# composite minimum moves ~±4% between identical-code runs on a single
# core (observed 0.91x-1.04x), so the floor alone makes this gate flaky.
grace = max(noise, 0.05)
print(f"bucket kernel stage-2a ({db['backend']}): {ratio:.3f}x vs best existing "
      f"(gate <= 1.0x, self-measured noise floor {noise:.3f})")
assert ratio <= 1.0 + grace, (
    f"batched stage-2a {ratio:.3f}x slower than the best existing backend "
    f"(noise floor {noise:.3f})")
searches = {n: r for n, r in rows.items() if n.startswith("bucket_kernel/search_")}
assert searches, "no bucket_kernel search rows"
for name, row in sorted(searches.items()):
    ds = dict(kv.split("=", 1) for kv in row["derived"].split(";"))
    print(f"{name}: identical={ds['identical']}, refines={ds['refines']}, "
          f"stage2_calls={ds['stage2_calls']}")
    assert ds["identical"] == "True", f"{name} top-k differs from brute force"
PY
fi

# PR 6 gates.
# (a) Fault-injection sweep: the reliability invariant (certified interval
#     containing the truth, or a typed error — never a silently wrong
#     top-k) at EVERY declared injection point.  The sweep parametrizes
#     over repro.reliability.injection_points() at collection time, so a
#     newly declared point cannot dodge it; zero collected tests (pytest
#     exit 5) fails the gate.
echo "== fault-injection sweep =="
python -m pytest -q -m faults tests/test_fault_injection.py

# (b) Reliability benchmark: durable snapshot round-trip on the 5k-set
#     corpus must reproduce the live store's top-k bit-for-bit, a flipped
#     snapshot byte must be DETECTED, the degraded deadline-floor answer
#     must stay sound, and the injected-fault retry path must recover
#     -> BENCH_PR6.json.
if [[ -z "${SKIP_BENCH:-}" ]]; then
  echo "== reliability benchmark (JSON -> BENCH_PR6.json) =="
  python -m benchmarks.run --only reliability --json BENCH_PR6.json
  python - <<'PY'
import json

rows = {r["name"]: r for r in json.load(open("BENCH_PR6.json"))["rows"]}
d = {n: dict(kv.split("=", 1) for kv in r["derived"].split(";"))
     for n, r in rows.items()}
restore = d["reliability/restore"]
detect = d["reliability/corrupt_detect"]
deg = d["reliability/degraded"]
rec = d["reliability/recovery"]
print(f"snapshot: save {rows['reliability/snapshot']['us_per_call']/1e3:.0f}ms, "
      f"restore {rows['reliability/restore']['us_per_call']/1e3:.0f}ms, "
      f"identical top-k: {restore['identical']}")
print(f"corrupt byte detected: {detect['detected']}; "
      f"degraded floor {deg['vs_full']} vs full cascade (sound: {deg['sound']}); "
      f"fault recovery {rec['overhead']} of a clean flush "
      f"(recovered: {rec['recovered']})")
assert restore["identical"] == "True", "restored snapshot's top-k differs"
assert detect["detected"] == "True", "corrupted snapshot NOT detected"
assert deg["sound"] == "True", "degraded result lost its certificate"
assert rec["recovered"] == "True", "service did not recover from injected fault"
PY
fi

# PR 7 gates.
# (a) Multi-query cascade + query-engine test slice (marker: multiquery);
#     zero collected tests (pytest exit 5) fails the gate.
echo "== multiquery test slice =="
python -m pytest -q -m multiquery tests/test_multiquery.py tests/test_engine.py

# (b) Query-axis backends' conformance slice, explicitly: the dynamic
#     loop above already sweeps every registered backend, but these rungs
#     are new in this PR — an empty slice (pytest exit 5) must fail
#     loudly, so the query-axis kernel cannot dodge certification.
echo "== multiquery conformance slice =="
MQ_BACKENDS=$(python -c "from repro.core import masked; print(' '.join(masked.MULTIQUERY_NATIVE_BACKENDS))")
echo "query-axis backends: ${MQ_BACKENDS}"
for be in ${MQ_BACKENDS}; do
  echo "-- conformance[${be}] --"
  python -m pytest -q -m conformance tests/conformance -k "${be}"
done

# (c) Batched multi-query throughput: ONE search_batch call at Q=64 on
#     the 5k-set corpus must reach >= 2.0x the sequential per-query
#     search() throughput, within the self-measured noise floor, with
#     per-query top-k bit-for-bit identical -> BENCH_PR7.json.
if [[ -z "${SKIP_BENCH:-}" ]]; then
  echo "== multiquery benchmark (JSON -> BENCH_PR7.json) =="
  python -m benchmarks.run --only multiquery --json BENCH_PR7.json
  python - <<'PY'
import json

rows = {r["name"]: r for r in json.load(open("BENCH_PR7.json"))["rows"]}
bat = dict(kv.split("=", 1) for kv in rows["multiquery/batched"]["derived"].split(";"))
seq = dict(kv.split("=", 1) for kv in rows["multiquery/sequential"]["derived"].split(";"))
noise = float(dict(kv.split("=", 1) for kv in
               rows["multiquery/selfnoise"]["derived"].split(";"))["noise_floor"])
ratio = float(bat["speedup_vs_sequential"])
grace = max(noise, 0.02)
print(f"multiquery: batched {float(bat['qps']):.1f} q/s vs sequential "
      f"{float(seq['qps']):.1f} q/s ({ratio:.2f}x; gate >= 2.0x, "
      f"noise floor {noise:.3f})")
print(f"refines/query={bat['refines_per_query']}, "
      f"dedup hit rate={bat['dedup_hit_rate']}, "
      f"launches={bat['launches']}, backend={bat['masked_backend']}")
assert bat["identical"] == "True", "batched top-k differs from sequential search()"
assert ratio >= 2.0 * (1.0 - grace), (
    f"batched multi-query only {ratio:.2f}x sequential "
    f"(gate >= 2.0x within noise {noise:.3f})")
PY
fi

# PR 8 gates.
# (a) Observability test slice (marker: obs): tracing layer contract,
#     connected per-request span trees across the engine's async/executor
#     boundaries, the fault.fired correlation sweep at every injection
#     point, and zero-emission disabled mode.  Zero collected tests
#     (pytest exit 5) fails the gate.
echo "== obs test slice =="
python -m pytest -q -m obs tests/test_obs.py tests/test_fault_injection.py

# (b) Overhead + schema: tracing DISABLED (the default) must cost < 5%
#     estimated on the 5k-set cascade bench (no-op site cost x sites per
#     search); tracing ENABLED < 15% vs disabled, within the run's
#     self-measured noise floor; and one enabled search's capture must be
#     schema-valid with a single connected rid -> BENCH_PR8.json.
if [[ -z "${SKIP_BENCH:-}" ]]; then
  echo "== obs benchmark (JSON -> BENCH_PR8.json) =="
  python -m benchmarks.run --only obs --json BENCH_PR8.json
  python - <<'PY'
import json

rows = {r["name"]: r for r in json.load(open("BENCH_PR8.json"))["rows"]}
d = {n: dict(kv.split("=", 1) for kv in r["derived"].split(";"))
     for n, r in rows.items()}
noise = float(d["obs/selfnoise"]["noise_floor"])
noop_pct = float(d["obs/noop_site"]["est_noop_overhead_pct"])
enabled_pct = float(d["obs/cascade_enabled"]["overhead_vs_disabled_pct"])
grace = max(noise, 0.02) * 100.0
print(f"obs disabled: estimated no-op overhead {noop_pct:.4f}% "
      f"(site {d['obs/noop_site']['site_ns']}ns x "
      f"{d['obs/noop_site']['sites_per_search']} sites; gate < 5%)")
print(f"obs enabled: {enabled_pct:+.2f}% vs disabled "
      f"(gate < 15% within noise floor {noise:.3f})")
assert noop_pct < 5.0, (
    f"disabled-mode no-op overhead estimate {noop_pct:.3f}% exceeds the 5% budget")
assert enabled_pct < 15.0 + grace, (
    f"enabled tracing overhead {enabled_pct:.2f}% exceeds 15% "
    f"(+{grace:.1f}% noise grace)")
assert d["obs/cascade_enabled"]["schema_valid"] == "True", (
    "enabled capture failed JSONL schema validation")
assert d["obs/cascade_enabled"]["rids"] == "1", (
    "one search did not yield a single-rid span tree")
PY
fi

# ---------------------------------------------------------------------------
# PR 9 gates — anytime certified approximate search (mode="anytime").
# (a) anytime test slice: ladder convergence properties, edge cases,
#     validation surface, serve/engine knob plumbing.  The marker is new
#     in this PR — an empty slice (pytest exit 5) must fail loudly.
echo "== anytime test slice =="
python -m pytest -q -m anytime tests/test_anytime_search.py

# (b) anytime conformance slice: the certified-recall harness, per
#     registered masked backend — interval containment vs a float64
#     oracle, recall honesty, and the ε = 0 bit-for-bit degeneracy.  A
#     backend collecting zero anytime conformance cases (pytest exit 5)
#     fails the gate, so a new kernel cannot dodge the anytime contract.
echo "== anytime conformance slice (certified-recall harness per backend) =="
for be in ${MASKED_BACKENDS}; do
  echo "-- anytime-conformance[${be}] --"
  python -m pytest -q -m "conformance and anytime" tests/conformance/test_anytime.py -k "${be}"
done

# (c) Anytime speed/recall gate: at ε = 5% of the corpus distance scale
#     on the separated-cluster 5k-set bench, anytime must be >= 2.0x the
#     exact cascade's wall clock (within self-measured noise) AT a
#     certified recall >= 0.95 — and must actually converge with the
#     same id set -> BENCH_PR9.json.
if [[ -z "${SKIP_BENCH:-}" ]]; then
  echo "== anytime benchmark (JSON -> BENCH_PR9.json) =="
  python -m benchmarks.run --only anytime --json BENCH_PR9.json
  python - <<'PY'
import json

rows = {r["name"]: r for r in json.load(open("BENCH_PR9.json"))["rows"]}
d = {n: dict(kv.split("=", 1) for kv in r["derived"].split(";"))
     for n, r in rows.items()}
a = d["anytime/anytime"]
speedup = float(a["speedup_vs_exact"])
recall = float(a["certified_recall"])
noise = float(d["anytime/selfnoise"]["noise_floor"])
grace = max(noise, 0.05)
print(f"anytime: {speedup:.2f}x vs exact (gate >= 2.0x within noise "
      f"{noise:.3f}) at certified recall {recall:.2f} (gate >= 0.95), "
      f"converged={a['converged']}, stage={a['stage']}")
assert speedup >= 2.0 * (1.0 - grace), (
    f"anytime speedup {speedup:.2f}x below the 2.0x gate "
    f"(noise grace {grace:.2f})")
assert recall >= 0.95, (
    f"certified recall {recall:.2f} below the 0.95 gate")
assert a["converged"] == "True", "anytime did not converge on the bench corpus"
assert a["same_id_set"] == "True", (
    "anytime returned a different id set than exact on the "
    "separated-cluster bench")
PY
fi

# ---------------------------------------------------------------------------
# PR 10 gates — mutable, sharded SetStore.
# (a) mutation test slice: tombstone delete/update semantics, generational
#     compaction, the stale-cache regression, snapshot v1/v2 migration, the
#     all-corrupt quarantine contract, and the unified deadline clock.  The
#     marker is new in this PR — an empty slice (pytest exit 5) fails loudly.
echo "== mutation test slice =="
python -m pytest -q -m mutation tests/test_mutation.py

# (b) sharded test slice (single-device shards=1 identity + validation; the
#     8-device subprocess identity test is marked slow and runs as gate (c)
#     in consolidated form below).
echo "== sharded test slice =="
python -m pytest -q -m "sharded and not slow" tests/test_sharded.py

# (c) sharded-identity gate: under 8 forced host devices, sharded search
#     AND search_batch must return bit-for-bit the single-device top-k on
#     a 5k-set clustered corpus — including after delete + compact.
echo "== sharded-identity gate (8 forced host devices, 5k sets) =="
XLA_FLAGS="--xla_force_host_platform_device_count=8" python - <<'PY'
import jax
import numpy as np

from repro.data.pointclouds import clustered_sets
from repro.hd import search, search_batch
from repro.index import SetStore

assert jax.device_count() == 8, jax.device_count()
key = jax.random.PRNGKey(20250717)
sets, _ = clustered_sets(key, 5000, 16, sizes=(64, 128, 256))
store = SetStore(dim=16)
store.add_many(sets)
rng = np.random.RandomState(10)
qs = [np.asarray(sets[i]).mean(axis=0) + rng.randn(96, 16).astype(np.float32) * 0.5
      for i in (0, 1, 2)]

for i, q in enumerate(qs):
    a = search(q, store, 10)
    b = search(q, store, 10, shards=8)
    assert np.array_equal(a.ids, b.ids), f"query {i}: sharded ids differ"
    assert np.array_equal(a.values, b.values), f"query {i}: sharded values differ"
for i, (x, y) in enumerate(zip(search_batch(qs, store, 10),
                               search_batch(qs, store, 10, shards=8))):
    assert np.array_equal(x.ids, y.ids), f"batch query {i}: sharded ids differ"
    assert np.array_equal(x.values, y.values), f"batch query {i}: values differ"

# mutate: the identity must survive tombstones + compaction
for sid in range(0, 5000, 4):
    store.delete(sid)
store.compact()
a = search(qs[1], store, 10)
b = search(qs[1], store, 10, shards=8)
assert np.array_equal(a.ids, b.ids) and np.array_equal(a.values, b.values), (
    "post-compaction sharded top-k differs from single-device")
print(f"sharded identity: 3 queries + batch + mutated corpus bit-for-bit "
      f"across 8 shards ({store.n_live} live after compaction)")
PY

# (d) mutation gate: delete 30% of the corpus, compact, and the cascade's
#     top-k must equal brute force over the SURVIVORS bit-for-bit.
echo "== mutation gate (delete 30% + compact == brute force over survivors) =="
python - <<'PY'
import jax
import numpy as np

from repro.data.pointclouds import clustered_sets
from repro.hd import search
from repro.index import SetStore

key = jax.random.PRNGKey(20250717)
sets, _ = clustered_sets(key, 2000, 16, sizes=(64, 128, 256))
store = SetStore(dim=16)
store.add_many(sets)
rng = np.random.RandomState(11)
victims = sorted(set(rng.choice(2000, size=600, replace=False).tolist()))
for sid in victims:
    store.delete(sid)
removed = store.compact()
assert store.n_live == 1400, store.n_live
q = np.asarray(sets[victims[0]]).mean(axis=0) + rng.randn(96, 16).astype(np.float32) * 0.5
res = search(q, store, 10)
ref = search(q, store, 10, method="exact")  # brute force skips tombstones
assert np.array_equal(res.ids, ref.ids), "mutated cascade ids differ from brute force"
assert np.array_equal(res.values, ref.values), "mutated cascade values differ"
assert not any(sid in victims for sid in res.ids.tolist()), (
    "a deleted set leaked into the top-k")
print(f"mutation gate: deleted 600/2000, compacted "
      f"{sum(removed.values())} slots in {len(removed)} buckets, "
      f"top-10 == brute force over the 1400 survivors")
PY

# (e) Sharded benchmark under 8 forced host devices: per-shard stage-0/1
#     span timings + mutation rows -> BENCH_PR10.json; every sharded row
#     must report bit-for-bit identity.
if [[ -z "${SKIP_BENCH:-}" ]]; then
  echo "== sharded benchmark (8 devices; JSON -> BENCH_PR10.json) =="
  XLA_FLAGS="--xla_force_host_platform_device_count=8" \
    python -m benchmarks.run --only sharded --json BENCH_PR10.json
  python - <<'PY'
import json

rows = {r["name"]: r for r in json.load(open("BENCH_PR10.json"))["rows"]}
d = {n: dict(kv.split("=", 1) for kv in r["derived"].split(";"))
     for n, r in rows.items()}
shard_rows = {n: v for n, v in d.items()
              if n.startswith("sharded/shards") and "identical" in v}
assert shard_rows, "no sharded/shardsN rows in BENCH_PR10.json"
for name, dv in sorted(shard_rows.items()):
    print(f"{name}: identical={dv['identical']}, "
          f"vs_baseline={dv['vs_baseline']}")
    assert dv["identical"] == "True", f"{name} top-k differs from single-device"
mut = d["sharded/mutated"]
print(f"sharded/mutated: survivor_identical={mut['survivor_identical']}, "
      f"sharded_survivor_identical={mut['sharded_survivor_identical']} "
      f"(n_live={mut['n_live']})")
assert mut["survivor_identical"] == "True", (
    "post-compaction top-k differs from brute force over survivors")
assert mut["sharded_survivor_identical"] == "True", (
    "post-compaction SHARDED top-k differs from brute force over survivors")
stage_rows = [n for n in rows if n.startswith(("sharded/stage0/", "sharded/stage1/"))]
assert stage_rows, "no per-shard stage-0/1 timing rows in BENCH_PR10.json"
for n in sorted(stage_rows):
    print(f"{n}: {rows[n]['us_per_call']:.0f}us ({rows[n]['derived']})")
PY
fi
