#!/usr/bin/env bash
# Standard builder loop: tier-1 tests + quick benchmark with machine-readable
# output.  Run from the repo root:
#
#   ./scripts/check.sh            # tests + quick bench -> BENCH_PR1.json
#   SKIP_BENCH=1 ./scripts/check.sh   # tests only
#
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 pytest =="
python -m pytest -x -q

if [[ -z "${SKIP_BENCH:-}" ]]; then
  # Separate path: BENCH_PR1.json is the committed cross-PR trajectory
  # (written by `--only backends --json`); the quick loop must not clobber
  # it with an incomparable row set.
  echo "== quick benchmark (JSON -> BENCH_QUICK.json) =="
  python -m benchmarks.run --quick --json BENCH_QUICK.json
fi
