"""Quickstart: one front door, three estimators, same synthetic cloud pair.

Everything goes through ``repro.hd.set_distance`` — the (variant, method,
backend) dispatch over the paper's estimator spectrum.  See docs/api.md
for the full matrix.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.hd import HDConfig, set_distance
from repro.data.pointclouds import higgs_like

key = jax.random.PRNGKey(0)
a, b = higgs_like(key, 50_000, 50_000)
print(f"clouds: A={a.shape}  B={b.shape}")

# Exact Hausdorff; backend="auto" picks the fused single-pass scan for this
# size/device (the Pallas kernel on TPU, its pure-JAX mirror elsewhere).
res = set_distance(a, b, measure=True)
h_exact = float(res.value)
t_exact = res.meta.elapsed_s
print(f"exact    H = {h_exact:.5f}   ({t_exact:.2f}s, backend={res.meta.backend})")

# ProHD: same call, method="prohd" — returns the estimate WITH its
# certified interval in the uniform HDResult.
est = set_distance(a, b, method="prohd", config=HDConfig(alpha=0.01), measure=True)
t_prohd = est.meta.elapsed_s
n_sel = int(est.stats["n_sel_a"]) + int(est.stats["n_sel_b"])
print(
    f"ProHD    Ĥ = {float(est.value):.5f}   err={abs(float(est.value)-h_exact)/h_exact*100:.3f}%  "
    f"({t_prohd:.2f}s, {t_exact/t_prohd:.0f}x faster, |A_sel|+|B_sel|={n_sel})"
)
print(
    f"certified interval: [{float(est.lower):.5f}, {float(est.upper):.5f}] "
    f"(contains H: {float(est.lower) <= h_exact <= float(est.upper)})"
)

# Random-sampling baseline: again the same call, method="sampling".
samp = set_distance(
    a, b, method="sampling", key=jax.random.PRNGKey(1), config=HDConfig(alpha=0.01)
)
print(
    f"random   Ĥ = {float(samp.value):.5f}   "
    f"err={abs(float(samp.value)-h_exact)/h_exact*100:.3f}%  "
    f"(subset={int(samp.stats['n_sampled'])})"
)
