"""Quickstart: ProHD vs exact vs sampling on a synthetic cloud pair.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax

from repro.core import ProHDConfig, hausdorff_tiled, prohd, random_sampling_hd
from repro.data.pointclouds import higgs_like

key = jax.random.PRNGKey(0)
a, b = higgs_like(key, 50_000, 50_000)
print(f"clouds: A={a.shape}  B={b.shape}")

t0 = time.perf_counter()
h_exact = float(hausdorff_tiled(a, b, block=4096))
t_exact = time.perf_counter() - t0
print(f"exact    H = {h_exact:.5f}   ({t_exact:.2f}s)")

t0 = time.perf_counter()
est = prohd(a, b, ProHDConfig(alpha=0.01))
jax.block_until_ready(est.hd)
t_prohd = time.perf_counter() - t0
print(
    f"ProHD    Ĥ = {float(est.hd):.5f}   err={abs(float(est.hd)-h_exact)/h_exact*100:.3f}%  "
    f"({t_prohd:.2f}s, {t_exact/t_prohd:.0f}x faster, |A_sel|+|B_sel|={int(est.n_sel_a)+int(est.n_sel_b)})"
)
print(
    f"certified interval: [{float(est.hd_proj):.5f}, {float(est.hd_proj)+float(est.bound):.5f}] "
    f"(contains H: {float(est.hd_proj) <= h_exact <= float(est.hd_proj)+float(est.bound)})"
)

hd_r, n_r = random_sampling_hd(jax.random.PRNGKey(1), a, b, 0.01)
print(f"random   Ĥ = {float(hd_r):.5f}   err={abs(float(hd_r)-h_exact)/h_exact*100:.3f}%  (subset={n_r})")
