"""End-to-end serving driver: batched ProHD set-distance requests
(deliverable b — the paper's kind is a metric service, so the e2e driver
serves batched requests).

    PYTHONPATH=src python examples/serve_prohd.py
"""
import time

import jax
import numpy as np

from repro.core import hausdorff_tiled
from repro.data.pointclouds import gaussian_mixture_pca, higgs_like, random_clouds
from repro.serve.server import ProHDService, ServeConfig

key = jax.random.PRNGKey(0)
svc = ProHDService(ServeConfig(alpha=0.05))

# heterogeneous request mix (different sizes/dims bucket separately)
requests = []
for i in range(6):
    k = jax.random.fold_in(key, i)
    n = [700, 900, 1500, 3000, 800, 2500][i]
    a, b = random_clouds(k, n, n - 100, 12)
    requests.append((svc.submit(a, b), a, b))

t0 = time.perf_counter()
results = svc.flush()
dt = time.perf_counter() - t0
print(f"served {len(results)} requests in {dt:.2f}s (incl. compile)\n")

for rid, a, b in requests:
    r = results[rid]
    h = float(hausdorff_tiled(a, b))
    ok = r["lower"] <= h * 1.0001
    print(
        f"req {rid}: n=({a.shape[0]},{b.shape[0]}) hd≈{r['hd']:.4f} "
        f"certified=[{r['lower']:.4f},{r['upper']:.4f}] exact={h:.4f} sound={ok}"
    )
