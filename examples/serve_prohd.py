"""End-to-end serving driver: batched ProHD set-distance requests
(deliverable b — the paper's kind is a metric service, so the e2e driver
serves batched requests).

The service itself dispatches through the ``repro.hd`` front door; the
exactness check below uses the same front door explicitly.

    PYTHONPATH=src python examples/serve_prohd.py
"""
import time

import jax

from repro.data.pointclouds import random_clouds
from repro.hd import set_distance
from repro.serve.server import ProHDService, ServeConfig

key = jax.random.PRNGKey(0)
svc = ProHDService(ServeConfig(alpha=0.05))

# heterogeneous request mix (different sizes/dims bucket separately)
requests = []
for i in range(6):
    k = jax.random.fold_in(key, i)
    n = [700, 900, 1500, 3000, 800, 2500][i]
    a, b = random_clouds(k, n, n - 100, 12)
    requests.append((svc.submit(a, b), a, b))

t0 = time.perf_counter()
results = svc.flush()
dt = time.perf_counter() - t0
print(f"served {len(results)} requests in {dt:.2f}s (incl. compile)\n")

for rid, a, b in requests:
    r = results[rid]
    h = float(set_distance(a, b, backend="tiled").value)
    ok = r["lower"] <= h * 1.0001
    print(
        f"req {rid}: n=({a.shape[0]},{b.shape[0]}) hd≈{r['hd']:.4f} "
        f"certified=[{r['lower']:.4f},{r['upper']:.4f}] exact={h:.4f} sound={ok}"
    )
