"""End-to-end training driver: a small GQA transformer LM for a few hundred
steps on CPU, with async checkpointing, fault-tolerant resume, and ProHD
drift monitoring of the model's own hidden states (the paper's technique as
a first-class training feature).

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.data.synth import lm_batch
from repro.hd import HDConfig
from repro.models import transformer as T
from repro.train import optimizer as opt_mod
from repro.train.loop import TrainConfig, fit, make_set_distance_metric

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--d-model", type=int, default=128)
args = ap.parse_args()

cfg = LMConfig(
    name="demo-lm", n_layers=4, d_model=args.d_model, n_heads=8, n_kv_heads=2,
    d_ff=4 * args.d_model, vocab=512, dtype=jnp.float32, attn_chunk=32, remat=False,
)
key = jax.random.PRNGKey(0)
params = T.init_lm_params(key, cfg)
n_params = sum(p.size for p in jax.tree.leaves(params))
print(f"model: {n_params/1e6:.1f}M params")

SEQ, BATCH = 64, 16
reference_hidden = {}

# Front-door drift metric: certified ProHD between hidden-state clouds.
drift_metric = make_set_distance_metric(
    variant="hausdorff", method="prohd", config=HDConfig(alpha=0.05)
)


def data_iter(start):
    i = start
    while True:
        yield lm_batch(jax.random.fold_in(key, i), cfg, BATCH, SEQ)
        i += 1


def drift_hook(p, info):
    """ProHD between current hidden states and the step-0 reference set."""
    batch = lm_batch(jax.random.fold_in(key, 999983), cfg, BATCH, SEQ)
    hidden, _ = T.lm_forward(p, batch["tokens"][:, :-1], cfg)
    flat = hidden.reshape(-1, cfg.d_model)
    if "ref" not in reference_hidden:
        reference_hidden["ref"] = flat
        return
    res = drift_metric(reference_hidden["ref"], flat)
    print(f"  [drift@{info['step']}] ProHD(hidden_t, hidden_0) = {float(res.value):.4f} "
          f"certified ≥ {float(res.lower):.4f}")


with tempfile.TemporaryDirectory() as ckpt_dir:
    tc = TrainConfig(steps=args.steps, log_every=25, ckpt_every=50,
                     ckpt_dir=ckpt_dir, drift_every=50)
    params, _, logs = fit(
        params=params,
        optimizer=opt_mod.adamw(lr=3e-4, weight_decay=0.01),
        loss_fn=lambda p, b: T.lm_loss(p, b, cfg),
        data_iter_fn=data_iter,
        cfg=tc,
        drift_hook=drift_hook,
        log_fn=lambda s, r: print(f"step {s:4d}: loss={r['loss']:.4f} ce={r['ce_loss']:.4f} dt={r['dt']*1e3:.0f}ms"),
    )
print(f"final loss: {logs[-1]['loss']:.4f} (from {logs[0]['loss']:.4f})")
