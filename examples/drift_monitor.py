"""Streaming drift monitoring with ProHD (the paper's vector-DB use case).

A reference embedding set is fixed; a stream of vectors arrives in batches.
After a distribution shift is injected, the certified lower bound crosses
the alert threshold.

    PYTHONPATH=src python examples/drift_monitor.py
"""
import jax
import jax.numpy as jnp

from repro.core.prohd import ProHDConfig
from repro.core.streaming import DriftMonitorConfig, check_drift, init_drift_monitor, observe

key = jax.random.PRNGKey(0)
dim = 32
reference = jax.random.normal(key, (2048, dim))

cfg = DriftMonitorConfig(window=1024, dim=dim, prohd=ProHDConfig(alpha=0.05), threshold=6.0)
state = init_drift_monitor(cfg, reference, jax.random.fold_in(key, 1))

for step in range(20):
    k = jax.random.fold_in(key, 100 + step)
    batch = jax.random.normal(k, (256, dim))
    if step >= 12:  # inject drift
        batch = batch * 1.5 + 4.0
    state = observe(state, batch)
    if step % 4 == 3:
        rep = check_drift(state, cfg)
        flag = "  << ALERT" if bool(rep.alert) else ""
        print(
            f"step {step:3d}: hd={float(rep.hd):7.3f}  "
            f"certified=[{float(rep.lower):7.3f}, {float(rep.upper):7.3f}]{flag}"
        )
