"""Streaming drift monitoring with ProHD (the paper's vector-DB use case).

A reference embedding set is fixed; a stream of vectors arrives in batches.
After a distribution shift is injected, the certified lower bound crosses
the alert threshold.  ``check_drift`` dispatches through the ``repro.hd``
front door; the final line cross-checks its interval against an exact
front-door call.

    PYTHONPATH=src python examples/drift_monitor.py
"""
import jax

from repro.core.prohd import ProHDConfig
from repro.core.streaming import DriftMonitorConfig, check_drift, init_drift_monitor, observe
from repro.hd import set_distance

key = jax.random.PRNGKey(0)
dim = 32
reference = jax.random.normal(key, (2048, dim))

cfg = DriftMonitorConfig(window=1024, dim=dim, prohd=ProHDConfig(alpha=0.05), threshold=6.0)
state = init_drift_monitor(cfg, reference, jax.random.fold_in(key, 1))

for step in range(20):
    k = jax.random.fold_in(key, 100 + step)
    batch = jax.random.normal(k, (256, dim))
    if step >= 12:  # inject drift
        batch = batch * 1.5 + 4.0
    state = observe(state, batch)
    if step % 4 == 3:
        rep = check_drift(state, cfg)
        flag = "  << ALERT" if bool(rep.alert) else ""
        print(
            f"step {step:3d}: hd={float(rep.hd):7.3f}  "
            f"certified=[{float(rep.lower):7.3f}, {float(rep.upper):7.3f}]{flag}"
        )

# sanity: the certified interval really brackets the exact distance
exact = set_distance(state.reference, state.buffer, measure=True)
rep = check_drift(state, cfg)
print(
    f"\nexact H = {float(exact.value):.3f} ({exact.meta.backend}, "
    f"{exact.meta.elapsed_s*1e3:.0f}ms)  in certified interval: "
    f"{float(rep.lower) <= float(exact.value) <= float(rep.upper)}"
)
