"""HD-k-NN retrieval over a 10k-set corpus — the paper's vector-DB story.

Builds a :class:`repro.index.SetStore` of 10,000 ragged point sets
(separated Gaussian clusters), then serves a top-10 Hausdorff-nearest-sets
query two ways through the same front door:

- ``repro.hd.search(...)``                  — the certified bound cascade
- ``repro.hd.search(..., method="exact")``  — brute force over the corpus

and checks the cascade returned the IDENTICAL top-k (it provably does —
candidates are only pruned when their certified lower bound exceeds the
k-th smallest certified upper bound).

    PYTHONPATH=src python examples/retrieval.py
"""
import time

import jax
import numpy as np

from repro.data.pointclouds import clustered_sets
from repro.hd import search
from repro.index import SetStore

N_SETS, D, K = 10_000, 16, 10

key = jax.random.PRNGKey(0)
sets, labels = clustered_sets(key, N_SETS, D, sizes=(64, 128, 256))

t0 = time.perf_counter()
store = SetStore(dim=D)
store.add_many(sets)
store.summaries()        # materialize the packed corpus up front
store.packed_buckets()
print(
    f"corpus: {store.n_sets} sets / {store.total_points} points packed into "
    f"buckets {list(store.bucket_capacities)} in {time.perf_counter()-t0:.2f}s"
)

# a fresh query blob near one cluster
rng = np.random.RandomState(1)
query = np.asarray(sets[42]).mean(axis=0) + rng.randn(128, D).astype(np.float32) * 0.5

res = search(query, store, K, measure=True)          # warm (compiles)
res = search(query, store, K, measure=True)
print(f"\ncascade top-{K} in {res.meta.elapsed_s*1e3:.0f}ms:")
for sid, v in zip(res.ids, res.values):
    print(f"  set {sid:5d}  (cluster {labels[sid]:2d})  H = {v:.4f}")
s = res.stats
print(
    f"stats: {s['candidates_scanned']} candidates -> "
    f"{s['stage0_pruned']} pruned by summary bounds, "
    f"{s['stage1_pruned']} by masked ProHD, "
    f"{s['exact_refines']} exact refines "
    f"(prune_fraction={s['prune_fraction']:.4f})"
)

ref = search(query, store, K, method="exact", measure=True)
same = np.array_equal(res.ids, ref.ids) and np.array_equal(res.values, ref.values)
print(
    f"\nbrute force: {ref.meta.elapsed_s:.1f}s "
    f"({ref.meta.elapsed_s/res.meta.elapsed_s:.0f}x slower), "
    f"identical top-{K}: {same}"
)
assert same
